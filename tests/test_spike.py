"""Spike-code invariants (hypothesis property tests on the core)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional hypothesis (skips without)

from repro.core import spike


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), t=st.sampled_from([7, 15, 31]),
       scale=st.floats(0.5, 4.0))
def test_roundtrip_error_bound(seed, t, scale):
    """|decode(encode(x)) - x| <= scale/(2T) for in-range, above-gate x."""
    x = jax.random.uniform(jax.random.PRNGKey(seed), (64,),
                           minval=-scale, maxval=scale)
    params = {"theta": jnp.zeros((64,)),
              "log_scale": jnp.full((64,), np.log(scale))}
    cfg = spike.SpikeConfig(T=t)
    y = spike.decode(spike.encode(x, params, cfg), params, cfg, jnp.float32)
    err = np.abs(np.array(y) - np.array(x))
    assert err.max() <= scale / (2 * t) + 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_gate_silences_below_threshold(seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (128,),
                           minval=-0.049, maxval=0.049)
    params = {"theta": jnp.full((128,), 0.05), "log_scale": jnp.zeros((128,))}
    cfg = spike.SpikeConfig(T=15)
    counts = spike.encode(x, params, cfg)
    assert np.abs(np.array(counts)).max() == 0.0


def test_faithful_equals_fused():
    x = jax.random.normal(jax.random.PRNGKey(0), (40, 80))
    params = spike.init_spike_params(80)
    cF = spike.encode(x, params, spike.SpikeConfig(T=15, faithful=True))
    cC = spike.encode(x, params, spike.SpikeConfig(T=15, faithful=False))
    np.testing.assert_array_equal(np.array(cF), np.array(cC))


def test_sparsity_loss_hinge():
    cfg = spike.SpikeConfig(T=10, target_rate=0.5, lam=1.0)
    dense = jnp.full((100,), 10.0)   # rate 1.0
    sparse = jnp.zeros((100,))
    assert float(spike.sparsity_loss(dense, 10, 0.5, 1.0)) > 0
    assert float(spike.sparsity_loss(sparse, 10, 0.5, 1.0)) == 0.0


def test_analytic_vjp_matches_autodiff():
    from repro.core import boundary
    cfg = spike.SpikeConfig(T=15)
    D = 48
    x = jax.random.normal(jax.random.PRNGKey(0), (29, D)) * 0.8
    theta = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (D,))) * 0.05
    ls = jax.random.normal(jax.random.PRNGKey(2), (D,)) * 0.3
    g = jax.random.normal(jax.random.PRNGKey(3), (29, D))
    _, vjp = jax.vjp(lambda a, t, l: boundary._local_roundtrip(
        a, {"theta": t, "log_scale": l}, boundary.HNN_FUSED), x, theta, ls)
    ref = vjp(g)
    out = spike.roundtrip_vjp(x, theta, ls, g, cfg)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4,
                                   atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500))
def test_pack4_lossless(seed):
    w = jax.random.randint(jax.random.PRNGKey(seed), (16, 30), 0, 15,
                           jnp.uint8)
    np.testing.assert_array_equal(np.array(spike.unpack4(spike.pack4(w))),
                                  np.array(w))
