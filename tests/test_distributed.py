"""Multi-device integration tests (subprocess: 8 fake CPU devices)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SCRIPT = os.path.join(HERE, "dist_scenarios.py")
ROOT = os.path.dirname(HERE)


def run(scenario, *args, timeout=520):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, SCRIPT, scenario, *args],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"{scenario}:\n{r.stdout}\n{r.stderr[-3000:]}"
    return r.stdout


def test_boundary_codecs_multidevice():
    run("boundary_codecs")


@pytest.mark.parametrize("group", [
    "gemma2-2b,granite-20b,qwen1.5-0.5b,qwen1.5-4b",
    "jamba-1.5-large-398b,llama4-maverick-400b-a17b",
    "qwen2-moe-a2.7b,qwen2-vl-2b",
    "rwkv-paper,seamless-m4t-medium,xlstm-125m",
])
def test_train_smoke_all_archs(group):
    out = run("train_archs", group)
    assert out.count("train OK") == len(group.split(","))


def test_decode_chain_consistency():
    run("decode_chain")


def test_mini_dryrun_compiles_with_collectives():
    run("mini_dryrun")


def test_elastic_checkpoint_reshard():
    run("elastic_checkpoint")


def test_compressed_gradient_psum():
    run("compressed_psum")


def test_analytic_matches_hlo_parse():
    run("analytic_crosscheck")


def test_decode_replicated_weights_equivalent():
    run("decode_replicated_weights")
