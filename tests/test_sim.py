"""NoC simulator: paper-claim ranges + structural properties."""
import math

import pytest
from _hyp import given, settings, st  # optional hypothesis (skips without)

from repro.sim.noc import NocConfig, NocSim, PAPER_MODELS, fc


def _ratios(model, **kw):
    layers = PAPER_MODELS[model]()
    reps = {m: NocSim(NocConfig(mode=m, **kw)).simulate(layers)
            for m in ("ann", "snn", "hnn")}
    a, s, h = reps["ann"], reps["snn"], reps["hnn"]
    return (a.latency_s / h.latency_s, a.total_energy / h.total_energy,
            reps)


def test_paper_baseline_ranges():
    """Fig 10/12 baseline: HNN speedup and energy gain in paper ranges."""
    for m in ("rwkv", "msresnet18", "efficientnet-b4"):
        lat, en, _ = _ratios(m)
        assert 1.0 <= lat <= 15.2, (m, lat)
        assert 0.95 <= en <= 10.0, (m, en)


def test_rwkv_smallest_margin():
    """Paper §5.3: RWKV (fewest chips) has the lowest HNN margin."""
    margins = {m: _ratios(m)[1] for m in PAPER_MODELS}
    assert margins["rwkv"] == min(margins.values())


def test_gain_grows_with_bits():
    """Fig 11: HNN speedup grows with activation bit width."""
    lats = [_ratios("msresnet18", bits=b)[0] for b in (8, 16, 32)]
    assert lats[0] < lats[1] < lats[2]


def test_sparsity_improves_latency():
    """Fig 7: more sparsity -> faster HNN inference."""
    h1 = NocSim(NocConfig(mode="hnn", spike_sparsity=0.8)).simulate(
        PAPER_MODELS["msresnet18"]())
    h2 = NocSim(NocConfig(mode="hnn", spike_sparsity=0.95)).simulate(
        PAPER_MODELS["msresnet18"]())
    assert h2.latency_s < h1.latency_s


def test_chip_scaling_claim():
    """§5.3: EfficientNet-B4 needs far more chips than RWKV/MS-ResNet."""
    chips = {m: NocSim(NocConfig(mode="hnn")).simulate(PAPER_MODELS[m]())
             .chips for m in PAPER_MODELS}
    assert chips["efficientnet-b4"] > 50 * chips["rwkv"]
    assert chips["efficientnet-b4"] > 10 * chips["msresnet18"]


@settings(max_examples=30, deadline=None)
@given(prev=st.integers(1, 4096), cur=st.integers(1, 4096))
def test_average_hops_eq4(prev, cur):
    sim = NocSim(NocConfig())
    h = sim.average_hops(prev, cur)
    assert h >= 1.0
    assert h == pytest.approx(
        abs(cur - prev) / 2.0 / NocConfig().grid + 1.0)


@settings(max_examples=20, deadline=None)
@given(n_in=st.integers(16, 4096), n_out=st.integers(16, 4096))
def test_energy_nonnegative_and_monotone_in_macs(n_in, n_out):
    cfg = NocConfig(mode="ann")
    r1 = NocSim(cfg).simulate([fc("a", n_in, n_out)])
    r2 = NocSim(cfg).simulate([fc("a", n_in, 2 * n_out)])
    assert 0 < r1.total_energy <= r2.total_energy


def test_emio_cost_from_trace_eq8():
    """The serving-trace bridge prices each step's wire bytes exactly on
    eq (8): floor(pb/nc)*cycles_ser + pb cycles, pb*e_d2d energy, with
    zero-byte and missing-field steps free."""
    from repro.sim.noc import emio_cost_from_trace

    cfg = NocConfig()
    nc = cfg.boundary_cores
    steps = [{"wire_bytes": 1000.0, "tokens": 4},
             {"wire_bytes": 0.0, "tokens": 2},
             {"tokens": 1},                       # no wire field: free
             {"wire_bytes": 7.0, "tokens": 1}]
    out = emio_cost_from_trace(steps, cfg)
    want_cycles = (math.floor(1000.0 / nc) * cfg.cycles_ser + 1000.0
                   + math.floor(7.0 / nc) * cfg.cycles_ser + 7.0)
    want_energy = (1000.0 + 7.0) * cfg.e_d2d
    assert out["steps"] == 4 and out["tokens"] == 8
    assert out["emio_cycles"] == pytest.approx(want_cycles)
    assert out["e_emio"] == pytest.approx(want_energy)
    assert out["emio_s"] == pytest.approx(want_cycles / cfg.freq_hz)
    assert out["emio_cycles_per_token"] == pytest.approx(want_cycles / 8)
    assert out["e_emio_per_token"] == pytest.approx(want_energy / 8)
    # an empty trace must not divide by zero
    empty = emio_cost_from_trace([], cfg)
    assert empty["tokens"] == 0 and empty["emio_cycles_per_token"] == 0.0
