"""NoC simulator: paper-claim ranges + structural properties."""
import math

import pytest
from _hyp import given, settings, st  # optional hypothesis (skips without)

from repro.sim.noc import NocConfig, NocSim, PAPER_MODELS, fc


def _ratios(model, **kw):
    layers = PAPER_MODELS[model]()
    reps = {m: NocSim(NocConfig(mode=m, **kw)).simulate(layers)
            for m in ("ann", "snn", "hnn")}
    a, s, h = reps["ann"], reps["snn"], reps["hnn"]
    return (a.latency_s / h.latency_s, a.total_energy / h.total_energy,
            reps)


def test_paper_baseline_ranges():
    """Fig 10/12 baseline: HNN speedup and energy gain in paper ranges."""
    for m in ("rwkv", "msresnet18", "efficientnet-b4"):
        lat, en, _ = _ratios(m)
        assert 1.0 <= lat <= 15.2, (m, lat)
        assert 0.95 <= en <= 10.0, (m, en)


def test_rwkv_smallest_margin():
    """Paper §5.3: RWKV (fewest chips) has the lowest HNN margin."""
    margins = {m: _ratios(m)[1] for m in PAPER_MODELS}
    assert margins["rwkv"] == min(margins.values())


def test_gain_grows_with_bits():
    """Fig 11: HNN speedup grows with activation bit width."""
    lats = [_ratios("msresnet18", bits=b)[0] for b in (8, 16, 32)]
    assert lats[0] < lats[1] < lats[2]


def test_sparsity_improves_latency():
    """Fig 7: more sparsity -> faster HNN inference."""
    h1 = NocSim(NocConfig(mode="hnn", spike_sparsity=0.8)).simulate(
        PAPER_MODELS["msresnet18"]())
    h2 = NocSim(NocConfig(mode="hnn", spike_sparsity=0.95)).simulate(
        PAPER_MODELS["msresnet18"]())
    assert h2.latency_s < h1.latency_s


def test_chip_scaling_claim():
    """§5.3: EfficientNet-B4 needs far more chips than RWKV/MS-ResNet."""
    chips = {m: NocSim(NocConfig(mode="hnn")).simulate(PAPER_MODELS[m]())
             .chips for m in PAPER_MODELS}
    assert chips["efficientnet-b4"] > 50 * chips["rwkv"]
    assert chips["efficientnet-b4"] > 10 * chips["msresnet18"]


@settings(max_examples=30, deadline=None)
@given(prev=st.integers(1, 4096), cur=st.integers(1, 4096))
def test_average_hops_eq4(prev, cur):
    sim = NocSim(NocConfig())
    h = sim.average_hops(prev, cur)
    assert h >= 1.0
    assert h == pytest.approx(
        abs(cur - prev) / 2.0 / NocConfig().grid + 1.0)


@settings(max_examples=20, deadline=None)
@given(n_in=st.integers(16, 4096), n_out=st.integers(16, 4096))
def test_energy_nonnegative_and_monotone_in_macs(n_in, n_out):
    cfg = NocConfig(mode="ann")
    r1 = NocSim(cfg).simulate([fc("a", n_in, n_out)])
    r2 = NocSim(cfg).simulate([fc("a", n_in, 2 * n_out)])
    assert 0 < r1.total_energy <= r2.total_energy


def test_emio_cost_from_trace_eq8():
    """The serving-trace bridge prices each step's wire bytes exactly on
    eq (8): floor(pb/nc)*cycles_ser + pb cycles, pb*e_d2d energy, with
    zero-byte and missing-field steps free."""
    from repro.sim.noc import emio_cost_from_trace

    cfg = NocConfig()
    nc = cfg.boundary_cores
    steps = [{"wire_bytes": 1000.0, "tokens": 4},
             {"wire_bytes": 0.0, "tokens": 2},
             {"tokens": 1},                       # no wire field: free
             {"wire_bytes": 7.0, "tokens": 1}]
    out = emio_cost_from_trace(steps, cfg)
    want_cycles = (math.floor(1000.0 / nc) * cfg.cycles_ser + 1000.0
                   + math.floor(7.0 / nc) * cfg.cycles_ser + 7.0)
    want_energy = (1000.0 + 7.0) * cfg.e_d2d
    assert out["steps"] == 4 and out["tokens"] == 8
    assert out["emio_cycles"] == pytest.approx(want_cycles)
    assert out["e_emio"] == pytest.approx(want_energy)
    assert out["emio_s"] == pytest.approx(want_cycles / cfg.freq_hz)
    assert out["emio_cycles_per_token"] == pytest.approx(want_cycles / 8)
    assert out["e_emio_per_token"] == pytest.approx(want_energy / 8)
    # an empty trace must not divide by zero
    empty = emio_cost_from_trace([], cfg)
    assert empty["tokens"] == 0 and empty["emio_cycles_per_token"] == 0.0


def test_emio_cost_from_trace_edge_cases():
    """Closed-form bridge corners: zero-token steps still price their
    bytes, mig_bytes-only steps count (migration bytes live inside
    wire_bytes), and the mig share is surfaced separately."""
    from repro.sim.noc import emio_cost_from_trace

    cfg = NocConfig()
    nc = cfg.boundary_cores
    steps = [
        {"wire_bytes": 500.0, "tokens": 0},               # drained tick
        {"wire_bytes": 300.0, "mig_bytes": 300.0,         # mig-only
         "tokens": 0},
    ]
    out = emio_cost_from_trace(steps, cfg)
    assert out["tokens"] == 0
    assert out["mig_bytes"] == pytest.approx(300.0)
    want = (math.floor(500.0 / nc) * cfg.cycles_ser + 500.0
            + math.floor(300.0 / nc) * cfg.cycles_ser + 300.0)
    assert out["emio_cycles"] == pytest.approx(want)
    # per-token figures guard the zero-token denominator
    assert out["emio_cycles_per_token"] == pytest.approx(want)


# ---------------------------------------------------------------------------
# cycle-level trace front-end (NocSim.simulate_trace)
# ---------------------------------------------------------------------------


def _trace():
    return [
        {"kind": "decode", "tokens": 4,
         "wire_streams": {"psum": 1000.0, "head_all_gather": 500.0,
                          "partial_combine": 120.0},
         "wire_bytes": 1620.0},
        {"kind": "decode", "tokens": 4, "wire_bytes": 900.0},  # no split
        {"kind": "drain", "tokens": 0, "wire_bytes": 300.0,
         "mig_bytes": 300.0, "wire_streams": {"kv_migrate": 300.0}},
        {"kind": "decode", "tokens": 2, "wire_bytes": 0.0},    # idle wire
    ]


def test_simulate_trace_exact_per_stream_pricing():
    """Each stream pays ceil(pb/nc)*cycles_ser + pb + cycles_des + hop
    fill; energy components follow §4.4 per packet."""
    from repro.sim.noc import NocSim

    cfg = NocConfig()
    nc = cfg.boundary_cores
    hops = cfg.grid / 4.0 + 1.0
    rep = NocSim(cfg).simulate_trace(_trace())
    assert len(rep.steps) == 4
    s0 = rep.steps[0]

    def cyc(pb):
        return (math.ceil(pb / nc) * cfg.cycles_ser + pb
                + cfg.cycles_des + hops)

    assert s0.cycles == pytest.approx(cyc(1000.0) + cyc(500.0)
                                      + cyc(120.0))
    tot0 = 1620.0
    assert s0.e_emio == pytest.approx(tot0 * cfg.e_d2d)
    assert s0.e_router == pytest.approx(tot0 * hops * cfg.e_hop)
    assert s0.e_pe == pytest.approx(tot0 * cfg.e_acc)
    assert s0.e_mem == pytest.approx(2.0 * tot0 * cfg.e_sram_rw)
    # a step without a stream split prices the aggregate as one stream
    assert rep.steps[1].cycles == pytest.approx(cyc(900.0))
    assert rep.steps[1].bytes_by_stream == {"total": 900.0}
    # zero-byte steps are free
    assert rep.steps[3].cycles == 0.0 and rep.steps[3].energy == 0.0
    assert rep.tokens == 10
    d = rep.to_dict()
    assert d["noc_cycles"] == pytest.approx(rep.total_cycles)
    assert d["joules_per_token"] == pytest.approx(
        rep.total_energy / 10 * 1e-12)
    assert set(d["energy_breakdown"]) == {"PE", "MEM", "Router", "EMIO"}
    assert d["wire_kb_by_stream"]["kv_migrate"] == pytest.approx(0.3)


def test_simulate_trace_bounds_closed_form():
    """Acceptance invariant: the cycle-level total is >= the closed-form
    eq (8) figure for the same trace — per-stream ceil plus deserialize
    and hop fill can only add cycles over floor-on-the-aggregate."""
    from repro.sim.noc import NocSim, emio_cost_from_trace

    for cfg in (NocConfig(), NocConfig(cores_per_chip=16),
                NocConfig(cores_per_chip=4)):
        sim = NocSim(cfg)
        for trace in (_trace(), [],
                      [{"tokens": 1, "wire_bytes": 3.0}],
                      [{"tokens": 0, "wire_streams": {"a": 1.0, "b": 1.0,
                                                      "c": 1.0}}]):
            cyc = sim.simulate_trace(trace).total_cycles
            closed = emio_cost_from_trace(trace, cfg)["emio_cycles"]
            assert cyc >= closed, (cfg.cores_per_chip, trace)


def test_simulate_trace_empty_and_streamless():
    """Edge cases: an empty trace and all-zero steps produce a valid,
    all-zero report (no division by zero in to_dict)."""
    from repro.sim.noc import NocSim

    rep = NocSim(NocConfig()).simulate_trace([])
    d = rep.to_dict()
    assert d["steps"] == 0 and d["tokens"] == 0
    assert d["noc_cycles"] == 0 and d["joules_per_token"] == 0.0
    rep2 = NocSim(NocConfig()).simulate_trace(
        [{"kind": "decode", "tokens": 0, "wire_bytes": 0.0}])
    assert rep2.total_cycles == 0.0 and rep2.total_energy == 0.0
    assert rep2.to_dict()["wire_kb_by_stream"] == {}
