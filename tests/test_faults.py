"""Fault-injection fuzz: greedy token-identity through every
graceful-degradation path.

The house rule the SLO harness is built on: a preempted / suspended /
replica-lost request restarts from scratch on re-admit, and under
greedy sampling the restarted stream is bit-identical to an
uninterrupted run — per-slot streams are batch-independent and greedy
ignores the PRNG key — so faults may only ever cost latency, never
change tokens.  These tests inject faults across the engine matrix
(``spec_k`` 0/2 x ``async_depth`` 0/1), force pool-pressure preemption
with a deliberately undersized page pool, and suspend/resume
mid-schedule, asserting every rid's output equals the fault-free
reference and that every engine drains slot-, page- and limbo-clean.

Engines are compiled once per (spec_k, async_depth, num_pages) cell and
reused across schedules — a drained engine is a clean engine, and that
reuse is itself part of the property.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

PREFILL_LEN = 16
MAX_SEQ = 32
NUM_SLOTS = 3
VOCAB = 256
EOS = 7

_ENGINES = {}
_MODEL = None
_REF = None


def _model():
    global _MODEL
    if _MODEL is None:
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.configs.reduced import reduced
        from repro.launch import specs as SP, train as TR
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
            dtype=jnp.float32, codec="none")
        cell = ShapeCell("serve_decode", MAX_SEQ, NUM_SLOTS, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        _MODEL = (cfg, mesh, params)
    return _MODEL


def _engine(spec_k=0, async_depth=0, num_pages=0):
    key = (spec_k, async_depth, num_pages)
    if key not in _ENGINES:
        from repro.serving import EngineConfig, ServingEngine
        cfg, mesh, params = _model()
        _ENGINES[key] = ServingEngine(cfg, mesh, params, EngineConfig(
            num_slots=NUM_SLOTS, max_seq=MAX_SEQ, prefill_len=PREFILL_LEN,
            page_size=8, eos_id=EOS, spec_k=spec_k,
            async_depth=async_depth, num_pages=num_pages))
    return _ENGINES[key]


def _reqs(schedule, seed=1234):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    return [Request(rid=i, prompt=list(rng.randint(0, VOCAB, plen)),
                    max_new_tokens=mnt)
            for i, (plen, mnt) in enumerate(schedule)]


def _clone(r):
    from repro.serving import Request
    return Request(rid=r.rid, prompt=r.prompt,
                   max_new_tokens=r.max_new_tokens)


SCHEDULE = [(16, 6), (3, 1), (16, 8), (1, 4), (9, 8), (16, 2), (5, 5)]


def _reference(schedule=None):
    """Fault-free outputs of SCHEDULE on the plain engine (cached)."""
    global _REF
    if schedule is not None:
        eng = _engine()
        res = eng.run([_clone(r) for r in _reqs(schedule)])
        _assert_drained(eng)
        return res
    if _REF is None:
        _REF = _reference(SCHEDULE)
    return _REF


def _assert_drained(engine):
    alloc = engine.cache.allocator
    assert engine.idle
    assert not engine._inflight, "uncommitted dispatched step"
    assert alloc._dispatched == alloc._committed, "unbalanced epochs"
    assert alloc.num_free == NUM_SLOTS, "slot leak"
    assert alloc.pages_in_use == 0, "page leak"
    assert alloc.pages_in_limbo == 0, "page stuck in deferred-free limbo"
    assert (alloc._len == 0).all(), "stale occupancy"
    assert (alloc.block_table == -1).all(), "stale block-table mapping"


def _run_with_injector(engine, reqs, plan, max_steps=2000):
    """Serve ``reqs`` with a ``FaultInjector`` striking between ticks;
    returns ({rid: tokens}, injector)."""
    from repro.serving import FaultInjector
    inj = FaultInjector(plan)
    for r in reqs:
        engine.submit(_clone(r))
    results = {}
    for _ in range(max_steps):
        for req, out in engine.step():
            results[req.rid] = out
        inj.on_step(engine)
        if engine.idle:
            break
    assert engine.idle, "fault run did not drain"
    return results, inj


# ---------------------------------------------------------------------------
# acceptance criterion: injected-fault identity over the engine matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_k,async_depth",
                         [(0, 0), (2, 0), (0, 1), (2, 1)])
def test_injected_faults_token_identity(spec_k, async_depth):
    """Preempt + replica-loss + suspend faults injected on a seeded
    schedule: every rid's greedy stream equals the fault-free reference,
    for all four (spec_k, async_depth) engine cells, and the engine
    drains clean."""
    from repro.serving import FaultPlan
    ref = _reference()
    eng = _engine(spec_k=spec_k, async_depth=async_depth)
    res, inj = _run_with_injector(
        eng, _reqs(SCHEDULE),
        FaultPlan(seed=3, p_preempt=0.15, p_replica_loss=0.1,
                  p_suspend=0.05, max_faults=6))
    assert inj.total_injected > 0, "fault plan never struck"
    assert res == ref, (spec_k, async_depth, inj.injected)
    assert eng.preemptions + eng.suspends >= inj.total_injected
    _assert_drained(eng)
    eng.reset_stats()


def test_pool_pressure_preemption_token_identity():
    """A pool sized below the schedule's concurrent demand forces
    evict + re-queue mid-decode (engine.preemptions > 0); outputs stay
    bit-identical to the roomy-pool reference, sync and async."""
    ref = _reference()
    for depth in (0, 1):
        eng = _engine(async_depth=depth, num_pages=5)
        res = eng.run([_clone(r) for r in _reqs(SCHEDULE)])
        assert eng.preemptions > 0, f"tight pool never preempted (d={depth})"
        assert res == ref, (depth, eng.preemptions)
        _assert_drained(eng)
        eng.reset_stats()


def test_pool_pressure_preemption_spec_token_identity():
    """Same tight pool through the speculative scheduler: verify-step
    ensure failures preempt too, and greedy spec acceptance keeps the
    streams identical."""
    ref = _reference()
    eng = _engine(spec_k=2, num_pages=5)
    res = eng.run([_clone(r) for r in _reqs(SCHEDULE)])
    assert eng.preemptions > 0
    assert res == ref
    _assert_drained(eng)
    eng.reset_stats()


def test_suspend_resume_token_identity():
    """Drain + snapshot + resume mid-schedule: the snapshot releases
    every slot and page, resumed requests restart from scratch, and the
    final outputs equal an uninterrupted run."""
    ref = _reference()
    eng = _engine()
    for r in _reqs(SCHEDULE):
        eng.submit(_clone(r))
    results = {}
    for _ in range(4):
        for req, out in eng.step():
            results[req.rid] = out
    snap = eng.suspend()
    assert eng.num_active == 0
    assert eng.cache.allocator.pages_in_use == 0
    assert eng.cache.allocator.pages_in_limbo == 0
    assert snap, "nothing was in flight at the suspend point"
    eng.resume(snap)
    for _ in range(2000):
        for req, out in eng.step():
            results[req.rid] = out
        if eng.idle:
            break
    assert results == ref
    assert eng.suspends == 1
    _assert_drained(eng)
    eng.reset_stats()


def test_suspend_preserves_committed_work():
    """``suspend()`` is work-preserving (the PR-8 bugfix): a
    mid-generation slot's committed tokens ride the snapshot as a
    ``_Resume`` entry and re-admission prefills ``prompt + committed``
    instead of regenerating token by token.  Outputs stay greedy-
    identical to an uninterrupted run AND ``tokens_generated`` equals
    the total delivered — the restart-from-scratch engine regenerated
    the pre-suspend tokens, so this count is exactly what the fix
    stops wasting."""
    from repro.serving.engine import _Resume
    # prompts short enough that prompt + committed always fits the
    # prefill window: every active slot must snapshot work-preserving
    schedule = [(6, 10), (4, 8), (5, 9), (6, 7)]
    eng = _engine()
    ref = _reference(schedule)
    eng.reset_stats()
    for r in _reqs(schedule):
        eng.submit(_clone(r))
    results = {}
    for _ in range(5):
        for req, out in eng.step():
            results[req.rid] = out
    snap = eng.suspend()
    resumed = [e for e in snap if isinstance(e, _Resume)]
    assert resumed, "no mid-generation slot carried committed work"
    assert all(isinstance(e, _Resume) for e in snap
               if getattr(e, "prior", None) is not None)
    preserved = sum(len(e.prior) for e in resumed)
    assert preserved > 0
    eng.resume(snap)
    for _ in range(2000):
        for req, out in eng.step():
            results[req.rid] = out
        if eng.idle:
            break
    assert results == ref
    # every token was generated exactly once across the suspension
    assert eng.tokens_generated == sum(len(v) for v in ref.values())
    assert eng.suspends == 1
    _assert_drained(eng)
    eng.reset_stats()


def test_limbo_blind_admission_regression():
    """Regression for the limbo-blind admission bug (PR-8): the old
    ``can_admit`` checked the free list alone, so an admit could claim
    the last fresh pages while the deferred-free limbo still owed pages
    to the pipeline — the very next ``ensure`` starved mid-flight.  On
    this exact trace the pre-fix engine raises ``PagePoolExhausted``
    with ``preempt=False`` (and burns a pipeline-drain bubble on the
    rescue path otherwise); the limbo-aware gate defers the admission
    one tick and the run completes preemption-free with identical
    tokens."""
    from repro.serving import (EngineConfig, Request, ServingEngine,
                               SlotAllocator)
    cfg, mesh, params = _model()
    rng = np.random.RandomState(0)
    A = Request(rid=0, prompt=list(rng.randint(0, 64, 6)),
                max_new_tokens=6)
    B = Request(rid=1, prompt=list(rng.randint(0, 64, 4)),
                max_new_tokens=2)
    C = Request(rid=2, prompt=list(rng.randint(0, 64, 6)),
                max_new_tokens=2)
    kw = dict(num_slots=3, max_seq=24, prefill_len=8, page_size=8)

    def drive(ecfg):
        e = ServingEngine(cfg, mesh, params, ecfg)
        e.submit(_clone(A)); e.submit(_clone(B))
        res = {}
        for _ in range(2):               # B retires at tick 2's commit:
            for r, o in e.step():        # its page parks in limbo while
                res[r.rid] = o           # tick 2's step is in flight
        e.submit(_clone(C))              # 1 fresh page left + 1 in limbo
        for _ in range(60):
            for r, o in e.step():
                res[r.rid] = o
            if e.idle:
                break
        assert e.idle
        return res, e

    ref, _ = drive(EngineConfig(**kw, num_pages=9))      # roomy pool
    # tight pool, pipelined, no preemption rescue: pre-fix this raised
    # PagePoolExhausted at tick 3 (C admitted against the limbo page)
    res, eng = drive(EngineConfig(**kw, num_pages=3, async_depth=1,
                                  preempt=False))
    assert res == ref
    assert eng.preemptions == 0
    # allocator-level statement of the same fix: limbo pages never
    # count toward admission (pre-fix can_admit(24) was True here)
    a = SlotAllocator(num_slots=2, max_seq=32, page_size=8, num_pages=4)
    s = a.alloc(8)
    a.note_dispatch()                    # a step is in flight...
    a.free(s)                            # ...so this page parks in limbo
    assert a.pages_in_limbo == 1
    assert not a.can_admit(24)           # 3 free pages, 1 owed: refuse
    assert a.can_admit(16)               # 2 pages genuinely available
    assert a.can_admit(24, after_flush=True)   # the drain counterfactual
    a.note_commit()
    assert a.can_admit(24)               # limbo drained: fresh again


def test_preempt_slot_on_free_slot_is_typed():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.preempt_slot(0)


def test_preempt_disabled_pool_exhaustion_propagates():
    """``preempt=False`` restores the raw typed error: the same tight
    pool that silently degrades by default now raises
    ``PagePoolExhausted`` mid-flight."""
    from repro.serving import (EngineConfig, PagePoolExhausted, Request,
                               ServingEngine)
    cfg, mesh, params = _model()
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        num_slots=NUM_SLOTS, max_seq=MAX_SEQ, prefill_len=PREFILL_LEN,
        page_size=8, eos_id=EOS, num_pages=5, preempt=False))
    rng = np.random.RandomState(0)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=list(rng.randint(0, VOCAB, 16)),
                           max_new_tokens=12))
    with pytest.raises(PagePoolExhausted):
        for _ in range(100):
            eng.step()


# ---------------------------------------------------------------------------
# hypothesis fuzz (skips cleanly when hypothesis is not installed)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.integers(1, PREFILL_LEN), st.integers(1, 8)),
                min_size=1, max_size=2 * NUM_SLOTS + 1),
       st.integers(0, 1 << 16),
       st.sampled_from([(0, 0), (2, 0), (0, 1), (2, 1)]))
def test_fuzz_fault_schedules_token_identity(schedule, fault_seed, cell):
    """Random schedules x random fault seeds x the engine matrix: greedy
    outputs always equal the fault-free run of the same schedule, and
    every engine drains clean."""
    from repro.serving import FaultPlan
    spec_k, async_depth = cell
    ref = _reference(schedule)
    eng = _engine(spec_k=spec_k, async_depth=async_depth)
    res, _ = _run_with_injector(
        eng, _reqs(schedule),
        FaultPlan(seed=fault_seed, p_preempt=0.1, p_replica_loss=0.08,
                  p_suspend=0.05, max_faults=8))
    assert res == ref, (cell, fault_seed)
    _assert_drained(eng)
    eng.reset_stats()
