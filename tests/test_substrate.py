"""Data pipeline, checkpointing, optimizer substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.optim import adamw
from repro.optim.compress import dequantize_i8, quantize_i8


def test_data_deterministic_restart():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=7)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 5, 100):
        x, y = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])


def test_data_host_sharding_disjoint_and_labels_shifted():
    cfg0 = DataConfig(global_batch=8, n_hosts=2, host_id=0, seq_len=8)
    cfg1 = DataConfig(global_batch=8, n_hosts=2, host_id=1, seq_len=8)
    b0 = SyntheticLM(cfg0).batch(3)
    b1 = SyntheticLM(cfg1).batch(3)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_data_has_learnable_structure():
    """The Markov skeleton must beat uniform entropy (Table-4 signal)."""
    cfg = DataConfig(vocab=64, seq_len=512, global_batch=8)
    b = SyntheticLM(cfg).batch(0)
    # bigram empirical entropy should be far below log2(64)=6 bits
    from collections import Counter
    pairs = Counter(zip(b["tokens"][:, :-1].ravel(),
                        b["tokens"][:, 1:].ravel()))
    ctx = Counter(b["tokens"][:, :-1].ravel())
    h = 0.0
    n = sum(pairs.values())
    for (a, c), k in pairs.items():
        p = k / ctx[a]
        h -= k / n * np.log2(p)
    assert h < 5.3, h


def test_prefetcher_ordering():
    cfg = DataConfig(global_batch=2, seq_len=8)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, start_step=10)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [10, 11, 12, 13]


def test_checkpoint_roundtrip_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    mgr.save(5, tree)
    tree2 = jax.tree.map(lambda x: x * 2, tree)
    mgr.save(10, tree2)
    assert mgr.latest_step() == 10
    restored, step = mgr.restore(tree)
    assert step == 10
    np.testing.assert_array_equal(np.array(restored["a"]),
                                  np.array(tree2["a"]))


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.committed_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = {"a": jnp.arange(10)}
    mgr.save(1, t, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init_opt_state(params)
    cfg = adamw.AdamWConfig(lr=0.3, warmup_steps=0, total_steps=200,
                            weight_decay=0.0)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw.apply_updates(params, g, opt, cfg=cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_schedule_warmup_monotone():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(12)]
    assert all(b >= a for a, b in zip(lrs[:10], lrs[1:11]))
    assert lrs[10] == pytest.approx(1.0, rel=0.05)


def test_int8_quant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256)) * 3
    w, s = quantize_i8(x)
    err = np.abs(np.array(dequantize_i8(w, s)) - np.array(x))
    amax = np.abs(np.array(x)).max(axis=-1, keepdims=True)
    assert (err <= amax / 127.0 + 1e-6).all()
