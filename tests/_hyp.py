"""Optional-hypothesis shim (dev dependency: ``pip install -e .[dev]``).

When hypothesis is installed this re-exports the real ``given`` /
``settings`` / ``st``.  Without it, ``@given`` collapses the property
test into a single zero-argument test that pytest-skips, so tier-1
collection never depends on the optional package.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    import pytest
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco
