"""Learned draft heads: defs/forward identity, frozen-trunk training,
checkpoint round-trip, and the typed engine-config surface.

The engine-in-the-loop drafter properties (greedy token identity across
drafter x spec_k x async_depth, the no-host-join pipelining assertion)
live in tests/test_engine_fuzz.py next to the other identity fuzz.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.configs.reduced import reduced
from repro.models import draft_heads as DH
from repro.models import params as PR


def _cfg():
    return reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")


# ---------------------------------------------------------------------------
# defs + forward
# ---------------------------------------------------------------------------


def test_defs_shapes_and_identity_init():
    """w2 = 0 at init makes every head exactly the identity — the
    garbage-tolerant untrained draft (argmax repeats the trunk's)."""
    cfg = _cfg()
    D = cfg.d_model
    hp = PR.init_params(DH.draft_head_defs(cfg, 3), jax.random.PRNGKey(0),
                        jnp.float32)
    assert hp["w1"].shape == (3, D, max(D // 2, 8))
    assert hp["w2"].shape == (3, max(D // 2, 8), D)
    assert np.asarray(hp["w1"]).any()       # w1 random, nonzero
    assert not np.asarray(hp["w2"]).any()   # w2 zeros: identity
    assert not np.asarray(hp["b1"]).any()
    assert DH.num_draft_heads({"draft_heads": hp}) == 3

    h = jax.random.normal(jax.random.PRNGKey(1), (2, 5, D), jnp.float32)
    z = DH.head_hiddens(hp, h)
    assert z.shape == (2, 5, 3, D)
    np.testing.assert_array_equal(
        np.asarray(z), np.broadcast_to(np.asarray(h)[:, :, None, :],
                                       z.shape))


def test_head_hidden_one_matches_all_heads():
    """The loss's per-head loop and the engine's all-heads einsum are the
    same function."""
    cfg = _cfg()
    D = cfg.d_model
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(2), 3)
    hp = PR.init_params(DH.draft_head_defs(cfg, 2, d_hidden=12), k0,
                        jnp.float32)
    hp["w2"] = 0.5 * jax.random.normal(k1, hp["w2"].shape, jnp.float32)
    h = jax.random.normal(k2, (4, D), jnp.float32)
    z_all = np.asarray(DH.head_hiddens(hp, h))
    for j in range(2):
        np.testing.assert_allclose(z_all[:, j],
                                   np.asarray(DH.head_hidden_one(hp, j, h)),
                                   rtol=1e-5, atol=1e-5)


def test_custom_hidden_width():
    cfg = _cfg()
    hp = PR.init_params(DH.draft_head_defs(cfg, 1, d_hidden=4),
                        jax.random.PRNGKey(0), jnp.float32)
    assert hp["w1"].shape[-1] == 4 and hp["w2"].shape[1] == 4


# ---------------------------------------------------------------------------
# frozen-trunk training
# ---------------------------------------------------------------------------


def test_draft_head_train_step_learns_and_freezes_trunk():
    """A few heads-only steps on a fixed batch: loss drops, draft_acc
    rises, and every trunk leaf is bit-identical before/after (the
    'frozen' in frozen-trunk)."""
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.optim import adamw

    cfg = _cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = SP.make_plan(cfg, ShapeCell("dh_train", 32, 2, "train"), mesh)
    n = 25
    step, pspecs, ospecs, _ = TR.make_draft_head_train_step(
        cfg, plan, mesh, 2, opt_cfg=adamw.AdamWConfig(
            lr=1e-2, warmup_steps=3, total_steps=n))
    assert "draft_heads" in pspecs
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    trunk_before = {k: np.asarray(v) for k, v in params.items()
                    if not isinstance(v, dict)}
    params["draft_heads"] = TR.init_draft_head_params(
        cfg, plan, mesh, jax.random.PRNGKey(1), 2)
    opt = adamw.init_opt_state(params["draft_heads"])
    batch = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                   global_batch=2)).batch(0)
    hist = []
    for _ in range(n):
        params, opt, m = step(params, opt, batch)
        hist.append({k: float(v) for k, v in m.items()})
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert hist[-1]["draft_acc"] >= hist[0]["draft_acc"]
    for k, v in trunk_before.items():
        np.testing.assert_array_equal(v, np.asarray(params[k]), err_msg=k)
    # the heads DID move
    assert np.asarray(params["draft_heads"]["w2"]).any()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_with_heads_subtree(tmp_path):
    """Trunk + heads checkpoint as ONE path-keyed manifest and restore
    bit-exactly; a trunk-only template still restores from a trunk-only
    checkpoint in the same format (path-keyed coexistence)."""
    from repro.checkpoint.manager import CheckpointManager

    cfg = _cfg()
    hp = PR.init_params(DH.draft_head_defs(cfg, 2), jax.random.PRNGKey(3),
                        jnp.float32)
    trunk = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    full = dict(trunk)
    full["draft_heads"] = hp
    opt = {"m": jnp.zeros((2, 3), jnp.float32)}

    mgr = CheckpointManager(str(tmp_path / "full"))
    mgr.save(7, (full, opt), blocking=True)
    tmpl = (jax.tree.map(jnp.zeros_like, full),
            jax.tree.map(jnp.zeros_like, opt))
    (back, opt_back), step = CheckpointManager(
        str(tmp_path / "full")).restore(tmpl)
    assert step == 7
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    mgr2 = CheckpointManager(str(tmp_path / "trunk_only"))
    mgr2.save(3, (trunk, opt), blocking=True)
    (trunk_back, _), step2 = mgr2.restore(
        (jax.tree.map(jnp.zeros_like, trunk),
         jax.tree.map(jnp.zeros_like, opt)))
    assert step2 == 3
    np.testing.assert_array_equal(np.asarray(trunk["w"]),
                                  np.asarray(trunk_back["w"]))


# ---------------------------------------------------------------------------
# typed engine-config surface
# ---------------------------------------------------------------------------


def test_heads_drafter_config_errors_are_typed():
    """Bad drafter name, heads without spec_k, heads without a trained
    subtree, too few heads — all EngineConfigError, all raised BEFORE
    the params tree is compiled against (params={} / minimal stubs)."""
    from repro.launch.mesh import make_mesh
    from repro.serving import EngineConfig, EngineConfigError, ServingEngine

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = _cfg()
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, {}, EngineConfig(
            num_slots=2, max_seq=32, drafter="medusa"))
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, {}, EngineConfig(
            num_slots=2, max_seq=32, spec_k=0, drafter="heads"))
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, {}, EngineConfig(
            num_slots=2, max_seq=32, spec_k=2, drafter="heads"))
    too_few = {"draft_heads": {"w1": np.zeros((1, 8, 4), np.float32)}}
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, too_few, EngineConfig(
            num_slots=2, max_seq=32, spec_k=2, drafter="heads"))
