"""Multi-device scenarios run in subprocesses (8 fake CPU devices).

Invoked by tests/test_distributed.py as:
    python tests/dist_scenarios.py <scenario>
Exit code 0 = pass.  XLA device-count env must be set before jax import,
which is why these run out-of-process (smoke tests elsewhere keep 1
device per the dry-run contract).
"""
import dataclasses
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


def mesh24():
    from repro.launch.mesh import make_mesh
    return make_mesh((2, 4), ("data", "model"))


def scenario_boundary_codecs():
    from repro.core import boundary, spike
    mesh = mesh24()
    D = 64
    bp = spike.init_spike_params(D)
    sm = lambda f, ins, outs: jax.shard_map(f, mesh=mesh, in_specs=ins,
                                            out_specs=outs, check_vma=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, D)) * 0.5
    for name, codec, tol in [
            ("none", boundary.ANN, 1e-6),
            ("int8", boundary.BoundaryCodec(mode="int8"), 0.02),
            ("spike", boundary.HNN_FAITHFUL, 0.2),
            ("spike_fused", boundary.HNN_FUSED, 0.2),
            ("spike_pack4", boundary.HNN_PACK4, 0.25),
            ("sparse_topk",
             boundary.BoundaryCodec(mode="sparse_topk", capacity=0.99), 0.3)]:
        def f(xx, t, l):
            return boundary.coded_all_gather(
                xx, {"theta": t, "log_scale": l}, codec, "model", axis=0)
        fm = sm(f, (P(("data", "model")), P(), P()), P("data"))
        y = fm(x, bp["theta"], bp["log_scale"])
        err = float(jnp.sqrt(jnp.mean((y - x) ** 2))
                    / jnp.sqrt(jnp.mean(x ** 2)))
        assert err <= tol, (name, err)
        g = jax.grad(lambda a, t, l: fm(a, t, l).sum())(
            x, bp["theta"], bp["log_scale"])
        assert np.isfinite(np.array(g)).all(), name
    # faithful == fused on the wire
    c1 = spike.encode(x, bp, spike.SpikeConfig(T=15, faithful=True))
    c2 = spike.encode(x, bp, spike.SpikeConfig(T=15, faithful=False))
    assert (np.array(c1) == np.array(c2)).all()
    print("boundary codecs OK")


def scenario_train_archs():
    from repro.configs import get_config, list_archs
    from repro.configs.base import smoke_shape
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    mesh = mesh24()
    cell = smoke_shape("train")
    names = sys.argv[2].split(",") if len(sys.argv) > 2 else list_archs()
    for name in names:
        cfg = reduced(get_config(name))
        plan = SP.make_plan(cfg, cell, mesh)
        step, *_ = TR.make_train_step(cfg, plan, mesh, with_optimizer=False)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        B, S = cell.global_batch, cell.seq_len
        tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab, jnp.int32)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
        if cfg.is_encdec:
            batch["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, S // 2, cfg.d_model),
                cfg.dtype) * 0.1
            batch["tokens"] = tok[:, :S // 2]
            batch["labels"] = batch["tokens"]
        if cfg.rope_kind == "mrope":
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
        loss, grads, metrics = step(params, batch)
        l = float(metrics["loss"])
        assert np.isfinite(l), (name, l)
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0, name
        print(f"train OK {name} loss={l:.3f}")


def scenario_decode_chain():
    import jax.tree_util as jtu
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR, serve as SV
    mesh = mesh24()
    for name, B in (("gemma2-2b", 2), ("jamba-1.5-large-398b", 1),
                    ("xlstm-125m", 2)):
        cfg = reduced(get_config(name)).replace(hnn_mode="ann")
        S = 16
        cell = ShapeCell("d", S, B, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        pre, *_ = SV.make_prefill_step(cfg, plan, mesh)
        dec, _, _ = SV.make_decode_step(cfg, plan, mesh)
        structs, _ = SP.decode_input_specs(plan)
        tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab, jnp.int32)
        logits_pre, _ = pre(params, {"tokens": tok, "labels": tok})

        def init_leaf(path, s):
            if any(getattr(p, "key", None) == "pp" for p in path):
                return jnp.full(s.shape, -1e30, s.dtype)
            return jnp.zeros(s.shape, s.dtype)
        cache = jtu.tree_map_with_path(
            init_leaf, structs["cache"],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        for t in range(S):
            logits_dec, cache = dec(params, cache, tok[:, t],
                                    jnp.asarray(t, jnp.int32))
        a = np.array(logits_pre, np.float32)
        b = np.array(logits_dec, np.float32)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 0.05, (name, err)
        print(f"decode chain OK {name} err={err:.4f}")


def scenario_mini_dryrun():
    """lower+compile train/decode on the 8-device mesh, parse collectives."""
    from repro.configs import get_config, SHAPES
    from repro.configs.base import ShapeCell
    from repro.launch import roofline as RL, specs as SP, train as TR
    from repro.optim import adamw
    mesh = mesh24()
    cfg = get_config("qwen1.5-0.5b")
    cell = ShapeCell("t", 512, 8, "train")
    plan = SP.make_plan(cfg, cell, mesh)
    step, *_ = TR.make_train_step(cfg, plan, mesh, with_optimizer=True)
    ap, _ = TR.abstract_sharded_params(cfg, plan)
    aopt = adamw.abstract_opt_state(ap)
    ab, _ = SP.train_input_specs(plan)
    compiled = step.lower(ap, aopt, ab).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax: one dict per partition
        cost = cost[0]
    assert cost.get("flops", 0) > 0
    stats = RL.parse_collectives(compiled.as_text())
    assert stats.wire_bytes > 0 and len(stats.counts) >= 2, stats.counts
    # bug regression: group sizes come from the HLO (replica_groups /
    # num_partitions), so wire bytes must be invariant to the caller's
    # default_group — the old hardwired n=2 guess mis-scaled tp=4 rings
    for dg in (2, 4, 16):
        alt = RL.parse_collectives(compiled.as_text(), default_group=dg)
        assert alt.wire_bytes == stats.wire_bytes, (dg, alt.wire_bytes,
                                                    stats.wire_bytes)
    assert all(op.group > 1 for op in stats.ops), \
        sorted({op.group for op in stats.ops})
    assert sum(stats.by_stream.values()) == stats.wire_bytes or \
        abs(sum(stats.by_stream.values()) - stats.wire_bytes) < 1e-6
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    print("mini dryrun OK:", dict(stats.counts), "streams:",
          sorted(stats.by_stream))


def scenario_serving_wire_streams():
    """Per-collective wire streams of a compiled serving engine on the
    (2, 4) mesh: ``wire_stream_profile()`` must classify the coded
    boundary's collectives into semantic streams (head_all_gather from
    the named scope at minimum, psum/all_gather from kind fallback),
    sum exactly to the scalar ``decode_wire_stats`` accounting, and —
    threaded through an ``SLOMonitor`` — reappear per tick in the step
    trace with the same totals the closed-form and cycle-level NoC
    bridges then price consistently (cycle-level >= closed form)."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.serving import (EngineConfig, Request, ServingEngine,
                               SLOMonitor)
    from repro.sim.noc import NocConfig, NocSim, emio_cost_from_trace
    mesh = mesh24()
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="hnn")).replace(
        dtype=jnp.float32, codec="spike_fused")
    kw = dict(num_slots=4, max_seq=24, prefill_len=8, page_size=8)
    plan = SP.make_plan(cfg, ShapeCell("serve_decode", kw["max_seq"],
                                       kw["num_slots"], "decode"), mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, EngineConfig(**kw))
    profile = eng.wire_stream_profile()
    dec = profile["decode"]
    assert "head_all_gather" in dec, sorted(dec)
    assert len(dec) >= 2, sorted(dec)
    stats, per_tok = eng.decode_wire_stats()
    ndev = 8
    assert abs(sum(dec.values()) - stats.wire_bytes * ndev) < 1e-6, (
        sum(dec.values()), stats.wire_bytes * ndev)
    # thread through a monitor over a real run: per-tick stream splits
    # must sum to the scalar wire bytes, and the cycle-level NoC figure
    # must bound the closed-form EMIO figure
    mon = SLOMonitor(wire_streams_per_step=profile)
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, cfg.vocab, 8)) for _ in range(4)]
    eng.observers.append(mon)
    eng.run([Request(rid=i, prompt=p, max_new_tokens=6)
             for i, p in enumerate(prompts)], on_step=mon.on_step)
    trace = mon.step_trace()
    assert any(s["wire_bytes"] > 0 for s in trace)
    for s in trace:
        assert abs(sum(s["wire_streams"].values()) - s["wire_bytes"]) \
            < 1e-6, s
    cosim = NocSim(NocConfig()).simulate_trace(trace)
    closed = emio_cost_from_trace(trace)
    assert cosim.total_cycles >= closed["emio_cycles"], (
        cosim.total_cycles, closed["emio_cycles"])
    print(f"serving wire streams OK: {sorted(dec)} "
          f"cyc={cosim.total_cycles:.0f}>=closed={closed['emio_cycles']:.0f}")


def scenario_elastic_checkpoint():
    """Save on (2,4) mesh, restore re-sharded onto (1,8)."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.configs.base import smoke_shape
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    import tempfile
    mesh_a = mesh24()
    mesh_b = make_mesh((1, 8), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b")).replace(
        d_model=64, n_heads=8, n_kv_heads=8)
    cell = smoke_shape("train")
    plan_a = SP.make_plan(cfg, cell, mesh_a)
    plan_b = SP.make_plan(cfg, cell, mesh_b)
    params = TR.init_sharded_params(cfg, plan_a, mesh_a,
                                    jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, params)
        _, pspecs_b, _ = TR.shard_params_specs(cfg, plan_b)
        restored, step = mgr.restore(params, mesh=mesh_b, specs=pspecs_b)
        assert step == 3
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("elastic checkpoint OK")


def scenario_compressed_psum():
    from repro.optim.compress import psum_compressed
    mesh = mesh24()
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 33)) * 2

    def f(g):
        out, err = psum_compressed(g, "model")
        return out, err
    fm = jax.shard_map(f, mesh=mesh, in_specs=P(("data", "model")),
                       out_specs=(P(("data", "model")),
                                  P(("data", "model"))), check_vma=False)
    out, err = fm(x)
    # reference: exact psum over model of replicated? x is sharded; each
    # model-group of 4 shards sums -> compare against exact groupwise sum
    xs = np.array(x).reshape(2, 4, 1, 33)
    exact = xs.sum(axis=1, keepdims=True).repeat(4, axis=1).reshape(8, 1, 33)[:, 0]
    rel = np.abs(np.array(out) - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.05, rel
    print("compressed psum OK rel", rel)




def scenario_analytic_crosscheck():
    """Analytic wire model vs HLO-parsed collectives (same mesh/plan).

    The parsed per-unit wire bytes must agree with the analytic per-unit
    boundary+FSDP bytes to within 2x (the analytic model intentionally
    ignores reshape paddings and sub-10%% glue collectives)."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.launch import analytic as AN, roofline as RL, specs as SP, \
        train as TR
    mesh = mesh24()
    cfg = get_config("qwen1.5-0.5b")
    cell = ShapeCell("t", 512, 8, "train")
    plan = SP.make_plan(cfg, cell, mesh)
    step, *_ = TR.make_train_step(cfg, plan, mesh, with_optimizer=False,
                                  microbatches=1)
    ap, _ = TR.abstract_sharded_params(cfg, plan)
    ab, _ = SP.train_input_specs(plan)
    compiled = step.lower(ap, ab).compile()
    stats = RL.parse_collectives(compiled.as_text())
    # structural expectation for the PARSED module (scan bodies counted
    # once): one unit's boundary+FSDP wire, plus the embedding/LM-head
    # weight gathers outside the scan (fwd + remat + grad-RS passes)
    w = AN.wire_bytes_per_elem(cfg.codec)
    tp, dp = 4, 2
    B_loc, S = 8 // dp, 512
    per_unit = AN.block_cost("attn", cfg, B_loc, S, tp, dp, w).wire
    D, Vp = cfg.d_model, cfg.vocab_padded(tp)
    emb_gather = (dp - 1) / dp * (Vp * D * 2.0 / tp)   # per fwd pass
    expected = per_unit * 3 + 2 * emb_gather * 4       # embed+head, ~4 passes
    ratio = stats.wire_bytes / max(expected, 1.0)
    assert 0.3 <= ratio <= 3.0, (stats.wire_bytes, expected, ratio)
    print(f"analytic crosscheck OK: parsed={stats.wire_bytes/1e6:.1f}MB "
          f"expected={expected/1e6:.1f}MB ratio={ratio:.2f}")


def scenario_decode_replicated_weights():
    """replicate_weights=True must be numerically identical to the
    FSDP-sharded decode path (same params, same logits)."""
    import jax.tree_util as jtu
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import serve as SV, specs as SP, train as TR
    mesh = mesh24()
    cfg = reduced(get_config("qwen1.5-0.5b")).replace(hnn_mode="ann")
    S, B = 16, 2
    cell = ShapeCell("d", S, B, "decode")
    plan = SP.make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    pre, *_ = SV.make_prefill_step(cfg, plan, mesh)
    dec_a, _, _ = SV.make_decode_step(cfg, plan, mesh,
                                      replicate_weights=False)
    dec_b, _, _ = SV.make_decode_step(cfg, plan, mesh,
                                      replicate_weights=True)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab,
                             jnp.int32)
    _, cache = pre(params, {"tokens": tok, "labels": tok})
    la, _ = dec_a(params, cache, tok[:, -1], jnp.asarray(S - 1, jnp.int32))
    _, cache2 = pre(params, {"tokens": tok, "labels": tok})
    lb, _ = dec_b(params, cache2, tok[:, -1], jnp.asarray(S - 1, jnp.int32))
    err = float(jnp.max(jnp.abs(la - lb)))
    assert err < 1e-2, err
    print("replicated-weight decode OK, max err", err)


def scenario_serving_parity():
    """Batched continuous-batching engine vs (a) a single-request run and
    (b) teacher-forced full-sequence argmax, token-for-token, for the
    ``none`` and ``spike_fused`` codecs (f32 to avoid bf16 argmax ties)."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import serve as SV, specs as SP, train as TR
    from repro.serving import EngineConfig, Request, ServingEngine
    mesh = mesh24()
    P_len, N = 16, 8
    for codec in ("none", "spike_fused"):
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
            dtype=jnp.float32, codec=codec)
        ecfg = EngineConfig(num_slots=4, max_seq=32, page_size=8)
        cell = ShapeCell("serve_decode", ecfg.max_seq, ecfg.num_slots,
                         "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = [list(rng.randint(0, cfg.vocab, P_len)) for _ in range(6)]

        # 6 greedy requests through 4 slots: slot reuse + interleaved admits
        engine = ServingEngine(cfg, mesh, params, ecfg)
        res = engine.run([Request(rid=i, prompt=p, max_new_tokens=N)
                          for i, p in enumerate(prompts)])
        assert engine.idle and len(res) == 6
        assert all(len(v) == N for v in res.values())

        # (a) batched == single-request, bit-for-bit
        solo = ServingEngine(cfg, mesh, params, ecfg).run(
            [Request(rid=0, prompt=prompts[0], max_new_tokens=N)])
        assert solo[0] == res[0], (codec, solo[0], res[0])

        # (a') async pipeline (dispatch t+1 before syncing t, device-
        # chained token feed, deferred retirement) == sync, bit-for-bit,
        # and it drains page/limbo-clean on the real dp x tp mesh
        asn = ServingEngine(cfg, mesh, params,
                            dataclasses.replace(ecfg, async_depth=1))
        res_a = asn.run([Request(rid=i, prompt=p, max_new_tokens=N)
                         for i, p in enumerate(prompts)])
        for i in range(6):
            assert res_a[i] == res[i], (codec, i, res[i], res_a[i])
        alloc = asn.cache.allocator
        assert alloc.pages_in_use == 0 and alloc.pages_in_limbo == 0
        assert (alloc.block_table == -1).all()

        # (b) engine decode == teacher-forced argmax over prompt+generated
        S = P_len + N
        planT = SP.make_plan(cfg, ShapeCell("tf", S, 8, "train"), mesh)
        logits_fn = SV.make_logits_step(cfg, planT, mesh)
        toks = np.zeros((8, S), np.int32)
        for i in range(6):
            toks[i] = prompts[i] + res[i]
        lg = np.asarray(logits_fn(params, {"tokens": jnp.asarray(toks),
                                           "labels": jnp.asarray(toks)}),
                        np.float32)
        am = lg.argmax(-1)
        for i in range(6):
            got = list(am[i, P_len - 1:P_len - 1 + N])
            assert got == res[i], (codec, i, res[i], got)
        print(f"serving parity OK {codec}")


def scenario_serving_sampling():
    """Distributed sampling from tp-sharded logits: greedy argmax equals
    the host argmax, top-k/top-p never sample outside their support, and
    temperature sampling hits high-probability tokens."""
    from repro.launch.mesh import make_mesh
    from repro.serving.sampling import SamplingConfig, sample
    from jax.sharding import PartitionSpec as P  # noqa: F811
    mesh = make_mesh((1, 8), ("data", "model"))
    B, V = 16, 512
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, V)) * 3.0
    key = jax.random.PRNGKey(7)

    def run(scfg, temps):
        f = jax.shard_map(
            lambda l, k, t: sample(l, k, t, tp="model", tp_size=8, cfg=scfg),
            mesh=mesh, in_specs=(P(None, "model"), P(), P()),
            out_specs=P(None), check_vma=False)
        return np.asarray(f(logits, key, temps))

    # greedy == host argmax
    tok = run(SamplingConfig(), jnp.zeros(B, jnp.float32))
    np.testing.assert_array_equal(tok, np.asarray(logits).argmax(-1))
    # top-k: every sample inside the global top-k set
    k = 8
    topk = np.argsort(np.asarray(logits), -1)[:, -k:]
    for s in range(3):
        tok = run(SamplingConfig(top_k=k),
                  jnp.full(B, 0.7 + 0.1 * s, jnp.float32))
        assert all(tok[b] in topk[b] for b in range(B)), s
    # top-p: sampled token always inside the minimal nucleus
    p = 0.6
    pr = jax.nn.softmax(jnp.asarray(logits, jnp.float32), -1)
    order = np.argsort(-np.asarray(pr), -1)
    csum = np.cumsum(np.take_along_axis(np.asarray(pr), order, -1), -1)
    tok = run(SamplingConfig(top_p=p), jnp.ones(B, jnp.float32))
    for b in range(B):
        nucleus = set(order[b, :int((csum[b] < p).sum()) + 1])
        assert tok[b] in nucleus, (b, tok[b])
    print("serving sampling OK")


def scenario_serving_spec_parity():
    """Speculative decoding invariant: with greedy sampling, spec_k>0 is
    token-identical to the vanilla engine for attention-family configs
    (``none`` and ``spike_fused`` codecs), the drafter accepts >1 token
    per verify step on a repetitive workload, and no pages leak through
    the accept/rollback path."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.serving import EngineConfig, Request, ServingEngine
    mesh = mesh24()
    P_len, N = 16, 24
    rng = np.random.RandomState(0)
    # repetitive prompts (greedy decode on random weights also falls into
    # cycles, which prompt-lookup then drafts correctly)
    base = [list(rng.randint(0, 256, 4)) for _ in range(3)]
    prompts = [base[i % 3] * 4 for i in range(6)]
    for codec in ("none", "spike_fused"):
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
            dtype=jnp.float32, codec=codec)
        cell = ShapeCell("serve_decode", 48, 4, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=N)
                        for i, p in enumerate(prompts)]
        vanilla = ServingEngine(cfg, mesh, params, EngineConfig(
            num_slots=4, max_seq=48, prefill_len=16, page_size=8))
        res_v = vanilla.run(reqs())
        spec = ServingEngine(cfg, mesh, params, EngineConfig(
            num_slots=4, max_seq=48, prefill_len=16, page_size=8,
            spec_k=3))
        res_s = spec.run(reqs())
        assert spec.spec_k == 3 and spec.spec_verifies > 0
        for i in range(6):
            assert res_s[i] == res_v[i], (codec, i, res_v[i], res_s[i])
        alloc = spec.cache.allocator
        assert alloc.pages_in_use == 0 and alloc.num_free == 4
        # async + speculative: drafting joins the pipeline (admits still
        # overlap the in-flight verify) — token streams stay identical
        spec_a = ServingEngine(cfg, mesh, params, EngineConfig(
            num_slots=4, max_seq=48, prefill_len=16, page_size=8,
            spec_k=3, async_depth=1))
        res_sa = spec_a.run(reqs())
        for i in range(6):
            assert res_sa[i] == res_v[i], (codec, i, res_v[i], res_sa[i])
        assert spec_a.cache.allocator.pages_in_limbo == 0
        assert spec_a.cache.allocator.pages_in_use == 0
        mal = spec.mean_accepted_len
        assert mal > 1.0, (codec, mal)
        assert spec.decode_steps < vanilla.decode_steps, (
            codec, spec.decode_steps, vanilla.decode_steps)
        _, per_tok = spec.verify_wire_stats(mal)
        assert per_tok > 0
        print(f"spec parity OK {codec} accepted={mal:.2f} "
              f"steps={spec.decode_steps}/{vanilla.decode_steps}")


def scenario_serving_paged_mixed():
    """Block-table paging payoff on the (2, 4) mesh: short prompts share
    the KV page pool with one long slot, the pool sized BELOW the dense
    per-slot reservation (16 vs 24 pages), and the token streams are
    identical to a dense-equivalent (full-pool) engine.  Pages shard
    over dp x tp while slots batch-shard over dp, so this also covers
    the group-partitioned allocator against real device placement."""
    from repro.configs import get_config
    from repro.configs.reduced import reduced
    from repro.launch import train as TR
    from repro.launch.specs import ShapeCell, make_plan
    from repro.serving import EngineConfig, Request, ServingEngine
    mesh = mesh24()
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    cell = ShapeCell("serve_decode", 48, 4, "decode")
    plan = make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    long_p = list(rng.randint(0, 256, 32))
    shorts = [list(rng.randint(0, 256, 8)) for _ in range(5)]

    def reqs():
        rs = [Request(rid=0, prompt=long_p, max_new_tokens=8)]
        rs += [Request(rid=i + 1, prompt=p, max_new_tokens=8)
               for i, p in enumerate(shorts)]
        return rs

    kw = dict(num_slots=4, max_seq=48, prefill_len=32, page_size=8)
    small = ServingEngine(cfg, mesh, params, EngineConfig(**kw,
                                                          num_pages=16))
    res_s = small.run(reqs())
    dense = ServingEngine(cfg, mesh, params, EngineConfig(**kw))
    res_d = dense.run(reqs())
    for rid in res_d:
        assert res_s[rid] == res_d[rid], (rid, res_d[rid], res_s[rid])
    ps = small.pool_stats()
    # the shrunk pool really is smaller than the dense reservation and
    # the workload peaked within it; everything drained back
    assert ps["num_pages"] == 16 < dense.num_pages
    assert ps["kv_bytes_pool"] < ps["kv_bytes_dense"]
    assert 0 < ps["peak_pages_in_use"] <= 16
    assert ps["pages_in_use"] == 0 and ps["kv_bytes_mapped"] == 0
    assert (small.cache.block_table == -1).all()
    print(f"paged mixed OK peak={ps['peak_pages_in_use']}/16 "
          f"poolMB={ps['kv_bytes_pool']/1e6:.2f} "
          f"denseMB={ps['kv_bytes_dense']/1e6:.2f}")


def scenario_serving_fused_parity():
    """Fused paged-decode kernel on the (2, 4) mesh: the compacted
    per-shard page lists really partition each slot's pages across the
    4 pool shards of its dp group, and the fused gather->flash->combine
    path is token-identical to the reference dense-gather path — for
    the plain and spike codecs, with the pool sized below the dense
    reservation so slots contend for pages, and (spike) through the
    speculative verify path (K1 > 1) as well."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.serving import EngineConfig, Request, ServingEngine
    mesh = mesh24()
    rng = np.random.RandomState(7)
    base = [list(rng.randint(0, 256, 4)) for _ in range(3)]
    prompts = ([base[i % 3] * 4 for i in range(4)]
               + [list(rng.randint(0, 256, 8)) for _ in range(3)])
    reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=10)
                    for i, p in enumerate(prompts)]
    kw = dict(num_slots=4, max_seq=48, prefill_len=16, page_size=8,
              num_pages=16)
    for codec in ("none", "spike_fused"):
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
            dtype=jnp.float32, codec=codec)
        cell = ShapeCell("serve_decode", 48, 4, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        ref = ServingEngine(cfg, mesh, params, EngineConfig(
            **kw, attn_kernel="reference"))
        res_r = ref.run(reqs())
        fus = ServingEngine(cfg, mesh, params, EngineConfig(
            **kw, attn_kernel="fused"))
        res_f = fus.run(reqs())
        for i in range(len(prompts)):
            assert res_f[i] == res_r[i], (codec, i, res_r[i], res_f[i])
        alloc = fus.cache.allocator
        # the engine really built 4-way compacted lists for this mesh
        assert alloc.shards_per_group == 4
        assert alloc.pages_per_shard == -(-alloc.pages_per_slot // 4)
        assert alloc.pages_in_use == 0
        assert (alloc.page_list_loc == -1).all()
        if codec == "spike_fused":
            spec = ServingEngine(cfg, mesh, params, EngineConfig(
                **kw, attn_kernel="fused", spec_k=3))
            res_s = spec.run(reqs())
            assert spec.spec_verifies > 0 and spec.mean_accepted_len > 1.0
            for i in range(len(prompts)):
                assert res_s[i] == res_r[i], (i, res_r[i], res_s[i])
        print(f"fused parity OK {codec}")


def scenario_serving_disagg_parity():
    """Disaggregated prefill/decode on the (2, 4) mesh: dp group 0 owns
    prefill, dp group 1 owns decode, and every admission hands the
    finished prefill's paged KV across in ONE coded ppermute onto pages
    the decode group mapped for it.  Token streams must be bit-identical
    to the colocated engine for BOTH wire formats — fp and the
    pow2-absmax int8 coded wire (whose scales are exact powers of two,
    so encode/decode is idempotent on the pool) — with migrations
    landing mid-trace under queue pressure, the coded wire moving fewer
    bytes, and both groups draining page/limbo-clean.  A hybrid
    (attention + mamba) leg checks recurrent state rows ride the same
    migration."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.serving import EngineConfig, Request, ServingEngine
    mesh = mesh24()
    P_len, N = 16, 8
    kw = dict(num_slots=4, max_seq=32, prefill_len=16, page_size=8)
    for codec in ("none", "spike_fused"):
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
            dtype=jnp.float32, codec=codec)
        cell = ShapeCell("serve_decode", kw["max_seq"], kw["num_slots"],
                         "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = [list(rng.randint(0, cfg.vocab, P_len)) for _ in range(6)]
        reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=N)
                        for i, p in enumerate(prompts)]
        ref = ServingEngine(cfg, mesh, params, EngineConfig(**kw)).run(
            reqs())
        wire = {}
        for kv_wire in ("fp", "coded"):
            eng = ServingEngine(cfg, mesh, params, EngineConfig(
                **kw, disagg=True, kv_wire=kv_wire))
            res = eng.run(reqs())
            for i in range(6):
                assert res[i] == ref[i], (codec, kv_wire, i, ref[i], res[i])
            # 6 admits through a 2-slot decode group: every one migrated,
            # the later ones mid-trace while earlier slots still decode
            assert eng.migrations == 6, (kv_wire, eng.migrations)
            assert eng.migrated_wire_bytes \
                == 6 * eng.cache.migrate_wire_bytes()
            wire[kv_wire] = eng.cache.migrate_wire_bytes()
            alloc = eng.cache.allocator
            assert alloc.pages_in_use == 0 and alloc.pages_in_limbo == 0
            assert (alloc.block_table == -1).all()
        assert wire["coded"] < wire["fp"], wire
        # the pipelined + speculative disagg engine rides the same coded
        # migration path and stays token-identical
        spec = ServingEngine(cfg, mesh, params, EngineConfig(
            **kw, disagg=True, kv_wire="coded", spec_k=2, async_depth=1))
        res_s = spec.run(reqs())
        for i in range(6):
            assert res_s[i] == ref[i], (codec, "spec", i, ref[i], res_s[i])
        assert spec.migrations == 6
        assert spec.cache.allocator.pages_in_limbo == 0
        print(f"serving disagg parity OK {codec} "
              f"wire={wire['coded']}/{wire['fp']}B")
    # hybrid family: slot-major mamba state rows migrate alongside the
    # paged attention KV (plain ppermute for state, coded for KV)
    cfg = reduced(get_config("jamba-1.5-large-398b", hnn_mode="ann")
                  ).replace(dtype=jnp.float32, codec="none")
    cell = ShapeCell("serve_decode", kw["max_seq"], kw["num_slots"],
                     "decode")
    plan = SP.make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(0, cfg.vocab, P_len)) for _ in range(4)]
    reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]
    ref = ServingEngine(cfg, mesh, params, EngineConfig(**kw)).run(reqs())
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        **kw, disagg=True, kv_wire="coded"))
    assert eng.cache.state_bytes_per_slot() > 0      # really hybrid
    res = eng.run(reqs())
    for i in range(4):
        assert res[i] == ref[i], ("jamba", i, ref[i], res[i])
    assert eng.migrations == 4
    print("serving disagg parity OK jamba")


def scenario_serving_disagg_fuzz():
    """One fuzz draw of disagg-vs-colocated identity, parameterized by
    argv: <spec_k> <async_depth> <codec> <kv_wire> <seed>.  The seed
    derives a random schedule (mixed prompt lengths, max_new, eos
    pressure); the disaggregated engine must be token-identical to the
    colocated one and drain clean.  Driven by the hypothesis property in
    tests/test_serving.py (and by fixed combos in the CI dist lane)."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.serving import EngineConfig, Request, ServingEngine
    spec_k, async_depth = int(sys.argv[2]), int(sys.argv[3])
    codec, kv_wire, seed = sys.argv[4], sys.argv[5], int(sys.argv[6])
    mesh = mesh24()
    hnn = "ann" if codec == "none" else "hnn"
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
        dtype=jnp.float32, codec=codec)
    kw = dict(num_slots=4, max_seq=32, prefill_len=16, page_size=8,
              eos_id=7)
    cell = ShapeCell("serve_decode", kw["max_seq"], kw["num_slots"],
                     "decode")
    plan = SP.make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    rng = np.random.RandomState(seed)
    reqs = lambda: [Request(rid=i,
                            prompt=list(rng.randint(0, 256, plen)),
                            max_new_tokens=int(mnt))
                    for i, (plen, mnt) in enumerate(
                        (int(rng.randint(1, 17)), rng.randint(1, 9))
                        for _ in range(int(rng.randint(1, 8))))]
    sched = reqs()
    clone = lambda: [Request(rid=r.rid, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens)
                     for r in sched]
    ref = ServingEngine(cfg, mesh, params, EngineConfig(**kw)).run(clone())
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        **kw, disagg=True, kv_wire=kv_wire, spec_k=spec_k,
        async_depth=async_depth))
    res = eng.run(clone())
    assert set(res) == set(ref)
    for i in ref:
        assert res[i] == ref[i], (i, ref[i], res[i])
    assert eng.migrations == len(sched)
    alloc = eng.cache.allocator
    assert alloc.pages_in_use == 0 and alloc.pages_in_limbo == 0
    assert (alloc.block_table == -1).all()
    print(f"disagg fuzz OK spec_k={spec_k} depth={async_depth} "
          f"{codec}/{kv_wire} seed={seed} n={len(sched)} "
          f"migrated={eng.migrated_wire_bytes}B")


def scenario_serving_spec_recurrent_fallback():
    """Recurrent-state families cannot roll back: the engine must force
    spec_k=0 and still serve correctly."""
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.serving import EngineConfig, Request, ServingEngine
    mesh = mesh24()
    cfg = reduced(get_config("xlstm-125m", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    cell = ShapeCell("serve_decode", 32, 4, "decode")
    plan = SP.make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=4, max_seq=32, prefill_len=16,
                        page_size=8, spec_k=3)
    eng = ServingEngine(cfg, mesh, params, ecfg)
    assert eng.spec_k == 0 and eng._verify is None
    rng = np.random.RandomState(0)
    res = eng.run([Request(rid=i, prompt=list(rng.randint(0, 256, 16)),
                           max_new_tokens=6) for i in range(4)])
    assert len(res) == 4 and all(len(v) == 6 for v in res.values())
    print("spec recurrent fallback OK")


def scenario_sampling_stats():
    """Statistical check of the fused distributed sampler at tp=8: the
    empirical distribution of >=2k draws matches a host-side reference
    softmax sampler (total-variation distance) for temperature-only,
    top-k, and top-p configurations."""
    from repro.launch.mesh import make_mesh
    from repro.serving.sampling import SamplingConfig, sample
    mesh = make_mesh((1, 8), ("data", "model"))
    from _ref_sampling import host_reference_probs
    V, DRAWS = 64, 4096
    rng = np.random.RandomState(5)
    row = rng.randn(V) * 2.0
    # one independent draw per batch row: per-slot independence turns a
    # [DRAWS, V] batch into DRAWS draws of the same distribution
    logits = jnp.asarray(np.broadcast_to(row, (DRAWS, V)), jnp.float32)
    temps = jnp.full(DRAWS, 0.7, jnp.float32)

    def host_ref(scfg):
        return host_reference_probs(row, 0.7, top_k=scfg.top_k,
                                    top_p=scfg.top_p)

    for name, scfg in [("temp", SamplingConfig()),
                       ("topk8", SamplingConfig(top_k=8)),
                       ("topp0.6", SamplingConfig(top_p=0.6))]:
        f = jax.shard_map(
            lambda l, k, t: sample(l, k, t, tp="model", tp_size=8, cfg=scfg),
            mesh=mesh, in_specs=(P(None, "model"), P(), P()),
            out_specs=P(None), check_vma=False)
        tok = np.asarray(f(logits, jax.random.PRNGKey(11), temps))
        emp = np.bincount(tok, minlength=V) / DRAWS
        ref = host_ref(scfg)
        tv = 0.5 * np.abs(emp - ref).sum()
        assert tv < 0.06, (name, tv)
        print(f"sampling stats OK {name} tv={tv:.4f}")


SCENARIOS = {k[len("scenario_"):]: v for k, v in list(globals().items())
             if k.startswith("scenario_")}

if __name__ == "__main__":
    SCENARIOS[sys.argv[1]]()
    print("PASS", sys.argv[1])
