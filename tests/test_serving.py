"""Serving engine tests: host-side scheduling logic in-process, model
parity + distributed sampling in 8-device subprocesses (see
dist_scenarios.py for why multi-device runs out-of-process)."""
import numpy as np
import pytest

from test_distributed import run


# ---------------------------------------------------------------------------
# host-side slot/page allocator (no devices involved)
# ---------------------------------------------------------------------------


def test_slot_allocator_reuse_and_pages():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=3, max_seq=64, page_size=16)
    assert a.num_free == 3 and a.total_pages == 12
    s0 = a.alloc(17)                      # 2 pages
    s1 = a.alloc(64)                      # 4 pages
    assert {s0, s1} == {0, 1}
    assert a.pages_used(s0) == 2 and a.pages_used(s1) == 4
    assert a.pages_in_use == 6
    a.extend(s0, 15)                      # 32 tokens -> still 2 pages
    assert a.pages_used(s0) == 2
    a.extend(s0, 1)                       # 33 tokens -> 3 pages
    assert a.pages_used(s0) == 3
    a.free(s1)
    assert a.num_free == 2 and a.pages_in_use == 3
    s2 = a.alloc(1)
    assert s2 == 2                        # FIFO free list
    a.free(s0)
    a.free(s2)
    s3 = a.alloc(5)
    assert s3 == s1                       # freed slot recycled
    with pytest.raises(ValueError):
        a.alloc(65)
    a.alloc(64)
    a.alloc(64)
    with pytest.raises(RuntimeError):     # pool exhausted
        a.alloc(1)


def test_slot_allocator_rejects_double_free():
    from repro.serving import SlotAllocator
    a = SlotAllocator(2, 8, 4)
    s = a.alloc(4)
    a.free(s)
    with pytest.raises(AssertionError):
        a.free(s)


# ---------------------------------------------------------------------------
# sampling, single-device path (tp_size == 1: pure local math)
# ---------------------------------------------------------------------------


def test_sampling_single_device_greedy_topk_topp():
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import SamplingConfig, sample
    B, V = 8, 128
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, V)) * 3.0
    key = jax.random.PRNGKey(3)
    zero = jnp.zeros(B, jnp.float32)

    tok = np.asarray(sample(logits, key, zero, tp=None, tp_size=1))
    np.testing.assert_array_equal(tok, np.asarray(logits).argmax(-1))

    # temps mix greedy + stochastic per slot in one call
    temps = jnp.asarray([0.0, 1.0] * (B // 2), jnp.float32)
    k = 4
    topk = np.argsort(np.asarray(logits), -1)[:, -k:]
    tok = np.asarray(sample(logits, key, temps, tp=None, tp_size=1,
                            cfg=SamplingConfig(top_k=k)))
    for b in range(B):
        if temps[b] == 0:
            assert tok[b] == np.asarray(logits)[b].argmax()
        else:
            assert tok[b] in topk[b]

    p = 0.5
    pr = np.asarray(jax.nn.softmax(logits.astype(jnp.float32), -1))
    order = np.argsort(-pr, -1)
    csum = np.cumsum(np.take_along_axis(pr, order, -1), -1)
    for s in range(3):
        tok = np.asarray(sample(logits, jax.random.PRNGKey(s),
                                jnp.ones(B, jnp.float32), tp=None,
                                tp_size=1, cfg=SamplingConfig(top_p=p)))
        for b in range(B):
            nucleus = set(order[b, :int((csum[b] < p).sum()) + 1])
            assert tok[b] in nucleus


# ---------------------------------------------------------------------------
# multi-device engine parity (subprocess)
# ---------------------------------------------------------------------------


def test_engine_matches_single_request_and_teacher_forced():
    """Prefill->decode parity: N-step batched engine decode (6 requests
    over 4 slots) equals the single-request run AND the teacher-forced
    forward argmax, across `none` and `spike_fused` boundary modes."""
    out = run("serving_parity")
    assert out.count("serving parity OK") == 2


def test_distributed_sampling_matches_host():
    run("serving_sampling")
