"""Serving engine tests: host-side scheduling logic in-process, model
parity + distributed sampling in 8-device subprocesses (see
dist_scenarios.py for why multi-device runs out-of-process)."""
import numpy as np
import pytest

from _ref_sampling import host_reference_probs
from test_distributed import run


# ---------------------------------------------------------------------------
# host-side slot/page allocator (no devices involved)
# ---------------------------------------------------------------------------


def test_slot_allocator_reuse_and_pages():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=3, max_seq=64, page_size=16)
    assert a.num_free == 3 and a.total_pages == 12
    s0 = a.alloc(17)                      # 2 pages
    s1 = a.alloc(64)                      # 4 pages
    assert {s0, s1} == {0, 1}
    assert a.pages_used(s0) == 2 and a.pages_used(s1) == 4
    assert a.pages_in_use == 6
    a.extend(s0, 15)                      # 32 tokens -> still 2 pages
    assert a.pages_used(s0) == 2
    a.extend(s0, 1)                       # 33 tokens -> 3 pages
    assert a.pages_used(s0) == 3
    a.free(s1)
    assert a.num_free == 2 and a.pages_in_use == 3
    s2 = a.alloc(1)
    assert s2 == 2                        # FIFO free list
    a.free(s0)
    a.free(s2)
    s3 = a.alloc(5)
    assert s3 == s1                       # freed slot recycled
    with pytest.raises(ValueError):
        a.alloc(65)
    a.alloc(64)
    a.alloc(64)
    with pytest.raises(RuntimeError):     # pool exhausted
        a.alloc(1)


def test_slot_allocator_rejects_double_free():
    from repro.serving import SlotAllocator
    a = SlotAllocator(2, 8, 4)
    s = a.alloc(4)
    a.free(s)
    with pytest.raises(ValueError):      # typed: must survive python -O
        a.free(s)


def test_slot_allocator_admit_when_full_raises():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=16, page_size=4)
    a.alloc(8)
    a.alloc(8)
    with pytest.raises(RuntimeError):
        a.alloc(1)                       # pool exhausted -> caller queues


def test_slot_allocator_evict_admit_no_stale_occupancy():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=16, page_size=4)
    s0 = a.alloc(16)                     # 4 pages
    assert a.pages_used(s0) == 4
    a.free(s0)
    assert a.pages_in_use == 0           # occupancy fully returned
    s1 = a.alloc(2)
    a.alloc(2)
    assert s1 in (0, 1)
    # the recycled slot starts from the NEW request's length, not the old
    assert a.pages_used(s1) == 1 and a.pages_in_use == 2


def test_slot_allocator_extend_matches_positions():
    """``extend`` accounting tracks the engine's ``_pos`` invariant:
    after admit at P tokens and n decode commits, occupancy == P + n
    (clipped at max_seq)."""
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=1, max_seq=16, page_size=4)
    s = a.alloc(5)
    pos = 5
    for _ in range(8):
        a.extend(s)
        pos += 1
        assert int(a._len[s]) == pos
    a.extend(s, 10)                      # would cross max_seq: clips
    assert int(a._len[s]) == 16
    assert a.pages_used(s) == 4


def test_slot_allocator_rollback_restores_occupancy():
    """Speculative accept/rollback: extend by the k+1 written positions,
    roll back to the committed length — occupancy lands exactly there."""
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=32, page_size=4)
    s = a.alloc(10)
    k = 3
    a.extend(s, k + 1)                   # verify wrote pos 10..13
    assert int(a._len[s]) == 14
    a.rollback(s, 12)                    # committed 2 of 4
    assert int(a._len[s]) == 12 and a.pages_used(s) == 3
    # rejecting everything but the fixup token
    a.extend(s, k + 1)
    a.rollback(s, 13)
    assert int(a._len[s]) == 13
    # near max_seq the extend clips; rollback still restores exactly
    a.extend(s, 100)
    assert int(a._len[s]) == 32
    a.rollback(s, 14)
    assert int(a._len[s]) == 14
    with pytest.raises(ValueError):
        a.rollback(s, 15)                # growth must go through extend
    with pytest.raises(ValueError):
        a.rollback(s, 0)                 # zero-length slot is `free`'s job


# ---------------------------------------------------------------------------
# n-gram drafter (host-side, deterministic)
# ---------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup_and_fallback():
    from repro.serving import NGramDrafter
    d = NGramDrafter([1, 2, 3, 9, 1, 2, 3])
    # suffix [1,2,3] matched at position 0 -> proposes its continuation
    assert d.propose(3) == [9, 1, 2]
    d.extend([9])                        # history ...1,2,3,9
    assert d.propose(2) == [1, 2]        # suffix [2,3,9] -> cont [1,2]
    # no n-gram recurrence: falls back to repeating the last token
    d2 = NGramDrafter([5, 6, 7, 8])
    assert d2.propose(3) == [8, 8, 8]
    # deterministic: same history, same proposal
    assert d.propose(2) == d.propose(2)
    with pytest.raises(ValueError):
        NGramDrafter([1], max_n=0)


# ---------------------------------------------------------------------------
# sampling, single-device path (tp_size == 1: pure local math)
# ---------------------------------------------------------------------------


def test_sampling_single_device_greedy_topk_topp():
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import SamplingConfig, sample
    B, V = 8, 128
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, V)) * 3.0
    key = jax.random.PRNGKey(3)
    zero = jnp.zeros(B, jnp.float32)

    tok = np.asarray(sample(logits, key, zero, tp=None, tp_size=1))
    np.testing.assert_array_equal(tok, np.asarray(logits).argmax(-1))

    # temps mix greedy + stochastic per slot in one call
    temps = jnp.asarray([0.0, 1.0] * (B // 2), jnp.float32)
    k = 4
    topk = np.argsort(np.asarray(logits), -1)[:, -k:]
    tok = np.asarray(sample(logits, key, temps, tp=None, tp_size=1,
                            cfg=SamplingConfig(top_k=k)))
    for b in range(B):
        if temps[b] == 0:
            assert tok[b] == np.asarray(logits)[b].argmax()
        else:
            assert tok[b] in topk[b]

    p = 0.5
    pr = np.asarray(jax.nn.softmax(logits.astype(jnp.float32), -1))
    order = np.argsort(-pr, -1)
    csum = np.cumsum(np.take_along_axis(pr, order, -1), -1)
    for s in range(3):
        tok = np.asarray(sample(logits, jax.random.PRNGKey(s),
                                jnp.ones(B, jnp.float32), tp=None,
                                tp_size=1, cfg=SamplingConfig(top_p=p)))
        for b in range(B):
            nucleus = set(order[b, :int((csum[b] < p).sum()) + 1])
            assert tok[b] in nucleus


# ---------------------------------------------------------------------------
# sampling statistics (tp_size == 1 in-process; tp > 1 in subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scfg_kw", [dict(), dict(top_k=8),
                                     dict(top_p=0.6)])
def test_sampling_statistics_match_host_reference(scfg_kw):
    """Total-variation distance between >=2k fused-sampler draws and the
    host reference softmax sampler, single-device path (tp_size == 1).
    Per-slot independence turns one [DRAWS, V] batch into DRAWS
    independent draws of the same distribution."""
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import SamplingConfig, sample
    V, DRAWS, TEMP = 64, 4096, 0.7
    rng = np.random.RandomState(5)
    row = rng.randn(V) * 2.0
    logits = jnp.asarray(np.broadcast_to(row, (DRAWS, V)), jnp.float32)
    tok = np.asarray(sample(logits, jax.random.PRNGKey(11),
                            jnp.full(DRAWS, TEMP, jnp.float32),
                            tp=None, tp_size=1,
                            cfg=SamplingConfig(**scfg_kw)))
    emp = np.bincount(tok, minlength=V) / DRAWS
    ref = host_reference_probs(row, TEMP, **scfg_kw)
    tv = 0.5 * np.abs(emp - ref).sum()
    assert tv < 0.06, (scfg_kw, tv)


def test_top_p_bisection_matches_sorted_cumsum_nucleus():
    """``_apply_top_p``'s bisected probability threshold must keep
    exactly the reference nucleus (smallest top-probability set with
    mass >= p) on random logits."""
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import _apply_top_p
    B, V = 16, 128
    lt = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (B, V)),
                    np.float64) * 3.0
    for p in (0.1, 0.3, 0.6, 0.9, 0.99):
        out = np.asarray(_apply_top_p(jnp.asarray(lt, jnp.float32), p,
                                      None, 1))
        kept = np.isfinite(out)
        probs = np.exp(lt - lt.max(-1, keepdims=True))
        probs = probs / probs.sum(-1, keepdims=True)
        for b in range(B):
            order = np.argsort(-probs[b])
            csum = np.cumsum(probs[b][order])
            n_ref = int((csum < p).sum()) + 1       # minimal nucleus size
            ref = np.zeros(V, bool)
            ref[order[:n_ref]] = True
            np.testing.assert_array_equal(kept[b], ref, err_msg=f"p={p}")


# ---------------------------------------------------------------------------
# multi-device engine parity + statistics (subprocess)
# ---------------------------------------------------------------------------


def test_engine_matches_single_request_and_teacher_forced():
    """Prefill->decode parity: N-step batched engine decode (6 requests
    over 4 slots) equals the single-request run AND the teacher-forced
    forward argmax, across `none` and `spike_fused` boundary modes."""
    out = run("serving_parity")
    assert out.count("serving parity OK") == 2


def test_distributed_sampling_matches_host():
    run("serving_sampling")


def test_distributed_sampling_statistics():
    """TV distance of the fused sampler vs the host reference at tp=8."""
    out = run("sampling_stats")
    assert out.count("sampling stats OK") == 3


def test_speculative_decoding_parity_and_acceptance():
    """Tentpole invariant: greedy spec decoding (spec_k=3) is
    token-identical to the vanilla engine for `none` and `spike_fused`,
    accepts >1 token per verify step on a repetitive workload, uses
    fewer device steps, and leaks no pages through accept/rollback."""
    out = run("serving_spec_parity")
    assert out.count("spec parity OK") == 2


def test_speculative_recurrent_fallback():
    """Recurrent-state families force spec_k=0 and still serve."""
    run("serving_spec_recurrent_fallback")
