"""Serving engine tests: host-side scheduling logic in-process, model
parity + distributed sampling in 8-device subprocesses (see
dist_scenarios.py for why multi-device runs out-of-process)."""
import numpy as np
import pytest

from _hyp import given, settings, st
from _ref_sampling import host_reference_probs
from test_distributed import run


# ---------------------------------------------------------------------------
# host-side slot + page-pool allocator (no devices involved)
# ---------------------------------------------------------------------------


def test_slot_allocator_reuse_and_pages():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=3, max_seq=64, page_size=16)
    assert a.num_free == 3 and a.total_pages == 12
    s0 = a.alloc(17)                      # 2 pages
    s1 = a.alloc(64)                      # 4 pages
    assert {s0, s1} == {0, 1}
    assert a.pages_used(s0) == 2 and a.pages_used(s1) == 4
    assert a.pages_in_use == 6
    a.extend(s0, 15)                      # 32 tokens -> still 2 pages
    assert a.pages_used(s0) == 2
    a.extend(s0, 1)                       # 33 tokens -> 3 pages
    assert a.pages_used(s0) == 3
    a.free(s1)
    assert a.num_free == 2 and a.pages_in_use == 3
    s2 = a.alloc(1)
    assert s2 == 2                        # FIFO free list
    a.free(s0)
    a.free(s2)
    s3 = a.alloc(5)
    assert s3 == s1                       # freed slot recycled
    with pytest.raises(ValueError):
        a.alloc(65)
    a.alloc(64)
    a.alloc(64)
    with pytest.raises(RuntimeError):     # pool exhausted
        a.alloc(1)


def test_slot_allocator_rejects_double_free():
    from repro.serving import SlotAllocator
    a = SlotAllocator(2, 8, 4)
    s = a.alloc(4)
    a.free(s)
    with pytest.raises(ValueError):      # typed: must survive python -O
        a.free(s)


def test_slot_allocator_admit_when_full_raises():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=16, page_size=4)
    a.alloc(8)
    a.alloc(8)
    with pytest.raises(RuntimeError):
        a.alloc(1)                       # pool exhausted -> caller queues


def test_slot_allocator_evict_admit_no_stale_occupancy():
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=16, page_size=4)
    s0 = a.alloc(16)                     # 4 pages
    assert a.pages_used(s0) == 4
    a.free(s0)
    assert a.pages_in_use == 0           # occupancy fully returned
    s1 = a.alloc(2)
    a.alloc(2)
    assert s1 in (0, 1)
    # the recycled slot starts from the NEW request's length, not the old
    assert a.pages_used(s1) == 1 and a.pages_in_use == 2


def test_slot_allocator_extend_matches_positions():
    """``extend`` accounting tracks the engine's ``_pos`` invariant:
    after admit at P tokens and n decode commits, occupancy == P + n.
    Crossing ``max_seq`` is a typed ``CacheOverflowError`` — the old
    silent clamp hid scheduler bugs (a slot must retire at max_seq,
    never keep decoding into it)."""
    from repro.serving import CacheOverflowError, SlotAllocator
    a = SlotAllocator(num_slots=1, max_seq=16, page_size=4)
    s = a.alloc(5)
    pos = 5
    for _ in range(8):
        a.extend(s)
        pos += 1
        assert int(a._len[s]) == pos
    with pytest.raises(CacheOverflowError):
        a.extend(s, 10)                  # would cross max_seq: typed
    assert int(a._len[s]) == 13          # ...and state is untouched
    assert a.pages_used(s) == 4
    assert issubclass(CacheOverflowError, ValueError)


def test_slot_allocator_rollback_restores_occupancy():
    """Speculative accept/rollback: ``ensure`` maps the k+1 positions a
    verify step writes, rollback returns the rejected tail — occupancy
    AND page mapping land exactly at the committed length."""
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=32, page_size=4)
    s = a.alloc(10)
    k = 3
    a.ensure(s, 10 + k + 1)              # verify writes pos 10..13
    assert int(a._len[s]) == 14
    a.rollback(s, 12)                    # committed 2 of 4
    assert int(a._len[s]) == 12 and a.pages_used(s) == 3
    # rejecting everything but the fixup token
    a.ensure(s, 12 + k + 1)
    a.rollback(s, 13)
    assert int(a._len[s]) == 13
    # near max_seq the engine clips its ensure; rollback still exact
    a.ensure(s, min(13 + 100, 32))
    assert int(a._len[s]) == 32
    a.rollback(s, 14)
    assert int(a._len[s]) == 14 and a.pages_used(s) == 4
    with pytest.raises(ValueError):
        a.rollback(s, 15)                # growth must go through ensure
    with pytest.raises(ValueError):
        a.rollback(s, 0)                 # zero-length slot is `free`'s job


def test_page_allocator_block_table_exact_and_disjoint():
    """Block-table rows mirror the mapping exactly: mapped prefixes are
    real page ids, the tail is -1, live rows are pairwise disjoint, and
    rollback/free return pages that a new slot can remap."""
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=3, max_seq=32, page_size=8, num_pages=6)
    s0 = a.alloc(17)                     # 3 pages
    s1 = a.alloc(8)                      # 1 page
    bt = a.block_table
    assert (bt[s0, :3] >= 0).all() and (bt[s0, 3:] == -1).all()
    assert (bt[s1, :1] >= 0).all() and (bt[s1, 1:] == -1).all()
    assert not set(bt[s0, :3]) & set(bt[s1, :1])
    a.rollback(s0, 9)                    # 3 -> 2 pages, page-exact
    assert (bt[s0, :2] >= 0).all() and (bt[s0, 2:] == -1).all()
    assert a.pages_in_use == 3
    s2 = a.alloc(24)                     # 3 pages from the returned pool
    live = [set(bt[s][bt[s] >= 0]) for s in (s0, s1, s2)]
    assert sum(len(x) for x in live) == len(set().union(*live))
    a.free(s1)
    assert (bt[s1] == -1).all()
    assert a.pages_in_use == 5


def test_page_allocator_typed_exhaustion():
    """``SlotsExhausted`` when slots run out, ``PagePoolExhausted`` when
    the pool does — slots can be free while pages are not, which is the
    regime a shrunk ``num_pages`` creates on purpose."""
    from repro.serving import (PagePoolExhausted, SlotAllocator,
                               SlotsExhausted)
    a = SlotAllocator(num_slots=4, max_seq=32, page_size=8, num_pages=4)
    s0 = a.alloc(32)                     # whole pool in one slot
    assert a.num_free == 3               # slots ARE free...
    assert not a.can_admit(1)
    with pytest.raises(PagePoolExhausted):
        a.alloc(1)                       # ...but no pages
    a.rollback(s0, 24)
    s1 = a.alloc(3)
    with pytest.raises(PagePoolExhausted):
        a.ensure(s1, 9)                  # live slot cannot grow either
    a.free(s0)
    for n in (8, 8, 8):
        a.alloc(n)
    with pytest.raises(SlotsExhausted):
        a.alloc(1)                       # now it IS the slot count
    assert issubclass(SlotsExhausted, RuntimeError)
    assert issubclass(PagePoolExhausted, RuntimeError)


def test_deferred_free_epoch_blocks_remap_until_commit():
    """Async overlap invariant: pages freed while a dispatched step's
    block-table snapshot may still name them park in limbo — they can
    NOT be remapped to a new slot until that step commits, at which
    point they rejoin the free pool exactly."""
    from repro.serving import PagePoolExhausted, SlotAllocator
    a = SlotAllocator(num_slots=3, max_seq=32, page_size=8, num_pages=4)
    s0 = a.alloc(16)                     # 2 pages
    old_pages = set(int(p) for p in a.block_table[s0][:2])
    a.note_dispatch()                    # step t snapshots s0's table
    a.free(s0)                           # retirement lands mid-flight
    assert a.pages_in_limbo == 2 and a.pages_in_use == 0
    # the freed pages are NOT available: only the 2 never-mapped pages
    # can back a new slot, so a 3-page request must fail typed even
    # though 4 - pages_in_use == 4
    assert not a.can_admit(17)
    with pytest.raises(PagePoolExhausted):
        a.alloc(17)
    s1 = a.alloc(16)                     # fits in the 2 untouched pages
    assert not set(int(p) for p in a.block_table[s1][:2]) & old_pages
    a.note_commit()                      # step t joined: limbo releases
    assert a.pages_in_limbo == 0
    s2 = a.alloc(16)                     # now the old pages remap
    assert set(int(p) for p in a.block_table[s2][:2]) == old_pages
    a.free(s1)
    a.free(s2)
    assert a.pages_in_use == 0 and a.pages_in_limbo == 0


def test_deferred_free_rollback_page_exact_under_overlap():
    """Speculative rollback while a step is in flight: the rejected
    tail's pages go to limbo (never straight back to the pool), the
    committed occupancy is exact, and with NO step in flight frees stay
    immediate — the sync engine's accounting is untouched."""
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=2, max_seq=32, page_size=4, num_pages=8)
    s = a.alloc(10)                      # 3 pages
    a.note_dispatch()
    a.ensure(s, 14)                      # verify window: 4 pages
    assert a.pages_used(s) == 4
    a.rollback(s, 11)                    # reject the tail mid-flight
    assert int(a._len[s]) == 11 and a.pages_used(s) == 3
    assert a.pages_in_limbo == 1
    a.note_commit()
    assert a.pages_in_limbo == 0
    # sync mode: dispatched == committed, frees are immediate
    a.ensure(s, 14)
    a.rollback(s, 11)
    assert a.pages_in_limbo == 0
    assert a.free_pages_in_group(0) == 8 - 3
    with pytest.raises(ValueError):      # commit without dispatch: typed
        a.note_commit()


def test_page_allocator_group_partitioning():
    """With dp groups, a slot only draws pages from its own group's
    contiguous range (device-side pages shard over dp x tp, so a slot's
    pages must live on its own dp group's shards)."""
    from repro.serving import PagePoolExhausted, SlotAllocator
    a = SlotAllocator(num_slots=4, max_seq=32, page_size=8, num_pages=8,
                      num_groups=2)
    assert a.pages_per_group == 4
    s0 = a.alloc(32)                     # slot 0 -> group 0, pages 0..3
    assert a.group_of(s0) == 0 and set(a.block_table[s0]) == {0, 1, 2, 3}
    # group 0 is now empty, but group 1's slots/pages still admit
    assert a.can_admit(32)
    s2 = a.alloc(32)                     # slots 2,3 -> group 1, pages 4..7
    assert a.group_of(s2) == 1 and set(a.block_table[s2]) == {4, 5, 6, 7}
    with pytest.raises(PagePoolExhausted):
        a.alloc(1)                       # slots 1 and 3 free, pools empty


def test_page_allocator_cross_group_migration_mirrors_placement():
    """``migrate_slot`` moves a slot's mapping to a fresh slot of another
    group with SHARD-MIRRORED placement: destination shard s holds the
    migrated page at the same compacted-list position and position
    offset as source shard s (the device handoff is one ppermute, no
    re-indexing), the source pages go through the ordinary free/limbo
    machinery, and no page leaks or double-maps across the move."""
    from repro.serving import SlotAllocator
    a = SlotAllocator(num_slots=4, max_seq=32, page_size=8, num_pages=32,
                      num_groups=2, shards_per_group=2)
    s = a.alloc(20)                                      # 3 pages, group 0
    src_loc = a.page_list_loc[s].copy()
    src_pos = a.page_list_pos[s].copy()
    src_cnt = [int(c) for c in a._shard_count[s]]
    assert a.can_migrate(s, 1) and not a.can_migrate(s, 0)
    assert a.placement_counts(1, 3) is not None
    assert a.can_place_mirror(1, src_cnt)
    a.note_dispatch()                    # a step is in flight: the freed
    d = a.migrate_slot(s, 1)             # source pages must limbo
    assert a.group_of(d) == 1 and a._len[s] == 0
    assert a.pages_in_limbo == 3 and a.pages_in_use == 3
    assert (a.page_list_loc[d] == src_loc).all()   # mirrored lists
    assert (a.page_list_pos[d] == src_pos).all()
    assert [int(c) for c in a._shard_count[d]] == src_cnt
    lo = a.pages_per_group
    used = a.block_table[d][a.block_table[d] >= 0]
    assert all(lo <= p < 2 * lo for p in used)     # dst group's range
    a.note_commit()
    assert a.pages_in_limbo == 0
    a.free(d)
    assert a.pages_in_use == 0 and (a.block_table == -1).all()


def test_page_allocator_peek_alloc_predicts_alloc():
    """``peek_alloc`` returns exactly the slot ``alloc`` then claims (or
    None exactly when ``alloc`` would raise) — the disagg router's
    pre-check contract."""
    from repro.serving import SlotAllocator
    from repro.serving.errors import PagePoolExhausted
    a = SlotAllocator(num_slots=4, max_seq=32, page_size=8, num_pages=8,
                      num_groups=2)
    assert a.peek_alloc(16) == a.alloc(16)
    assert a.peek_alloc(16, groups=(1,)) == a.alloc(16, groups=(1,))
    assert a.peek_alloc(32) is None      # no group has 4 pages left
    with pytest.raises(PagePoolExhausted):
        a.alloc(32)
    assert a.peek_alloc(16, groups=(0,)) == a.alloc(16, groups=(0,))


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(1, 40)),
                min_size=1, max_size=60),
       st.integers(1, 3))
def test_fuzz_page_allocator_never_leaks_or_double_maps(ops, groups):
    """Hypothesis fuzz of the page allocator: ANY alloc/ensure/rollback/
    free/preempt sequence — interleaved with note_dispatch/note_commit
    epoch marks, so frees land in the deferred-free limbo whenever a
    step is "in flight" — keeps (a) every page mapped at most once, (b)
    live slots' block-table rows disjoint and exactly mirroring the
    mapping, (c) free + mapped + limbo == num_pages, (d) failed ops
    state-neutral, (e) limbo empty whenever no step is outstanding.
    The preempt op (6) frees the YOUNGEST live slot mid-epoch — the
    allocator-level footprint of the engine's pool-pressure preemption
    — and must be page-clean like any other free.  The migrate op (7)
    moves a live slot to another group (the disaggregated prefill ->
    decode handoff): the destination mapping must mirror per shard, the
    source pages must limbo/free exactly like an evict, and a refused
    migration (no mirror capacity) must be state-neutral."""
    from repro.serving import SlotAllocator
    from repro.serving.errors import (CacheOverflowError,
                                      PagePoolExhausted, SlotsExhausted)
    a = SlotAllocator(num_slots=3 * groups, max_seq=32, page_size=8,
                      num_pages=6 * groups, num_groups=groups)
    live = {}                            # slot -> len
    order = []                           # admission order (preempt victim
    #                                      selection is youngest-first)

    def check():
        mapped = []
        for s in range(a.num_slots):
            row = a.block_table[s]
            used = a.pages_used(s)
            assert (row[:used] >= 0).all() and (row[used:] == -1).all()
            if s in live:
                assert used == -(-live[s] // a.page_size)
                grp = a.group_of(s)
                lo = grp * a.pages_per_group
                assert all(lo <= p < lo + a.pages_per_group
                           for p in row[:used])
            else:
                assert used == 0
            mapped += list(row[:used])
        assert len(mapped) == len(set(mapped)), "double-mapped page"
        free_total = sum(a.free_pages_in_group(g) for g in range(groups))
        assert free_total + len(mapped) + a.pages_in_limbo \
            == a.num_pages, "page leak"
        if a._dispatched == a._committed:
            assert a.pages_in_limbo == 0, "limbo outlived its epochs"

    for op, arg in ops:
        try:
            if op == 0:
                s = a.alloc(min(arg, 32))
                live[s] = min(arg, 32)
                order.append(s)
            elif op == 1 and live:
                s = sorted(live)[arg % len(live)]
                a.ensure(s, live[s] + arg)
                live[s] = max(live[s], live[s] + arg)
            elif op == 2 and live:
                s = sorted(live)[arg % len(live)]
                new_len = max(1, live[s] - arg)
                a.rollback(s, new_len)
                live[s] = new_len
            elif op == 3 and live:
                s = sorted(live)[arg % len(live)]
                a.free(s)
                del live[s]
                order.remove(s)
            elif op == 4 and a._dispatched - a._committed < 2:
                a.note_dispatch()        # a step starts: frees now defer
            elif op == 5 and a._dispatched > a._committed:
                a.note_commit()          # oldest step joins: limbo drains
            elif op == 6 and live:
                s = order[-1]            # preempt: evict the youngest
                a.free(s)                # (its pages limbo mid-epoch)
                del live[s]
                order.pop()
            elif op == 7 and live and groups > 1:
                s = sorted(live)[arg % len(live)]
                dst = (a.group_of(s) + 1 + arg) % groups
                if dst != a.group_of(s):
                    expect = a.can_migrate(s, dst)
                    d = a.migrate_slot(s, dst)   # raises iff not expect
                    assert expect and a.group_of(d) == dst
                    live[d] = live.pop(s)
                    order[order.index(s)] = d    # age travels with it
        except (SlotsExhausted, PagePoolExhausted, CacheOverflowError):
            pass                         # typed refusals must not mutate
        check()
    while a._dispatched > a._committed:
        a.note_commit()
    for s in sorted(live):
        a.free(s)
    assert a.pages_in_use == 0 and a.num_free == a.num_slots
    assert a.pages_in_limbo == 0
    assert (a.block_table == -1).all()


# ---------------------------------------------------------------------------
# n-gram drafter (host-side, deterministic)
# ---------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup_and_fallback():
    from repro.serving import NGramDrafter
    d = NGramDrafter([1, 2, 3, 9, 1, 2, 3])
    # suffix [1,2,3] matched at position 0 -> proposes its continuation
    assert d.propose(3) == [9, 1, 2]
    d.extend([9])                        # history ...1,2,3,9
    assert d.propose(2) == [1, 2]        # suffix [2,3,9] -> cont [1,2]
    # no n-gram recurrence: falls back to repeating the last token
    d2 = NGramDrafter([5, 6, 7, 8])
    assert d2.propose(3) == [8, 8, 8]
    # deterministic: same history, same proposal
    assert d.propose(2) == d.propose(2)
    with pytest.raises(ValueError):
        NGramDrafter([1], max_n=0)


# ---------------------------------------------------------------------------
# sampling, single-device path (tp_size == 1: pure local math)
# ---------------------------------------------------------------------------


def test_sampling_single_device_greedy_topk_topp():
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import SamplingConfig, sample
    B, V = 8, 128
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, V)) * 3.0
    key = jax.random.PRNGKey(3)
    zero = jnp.zeros(B, jnp.float32)

    tok = np.asarray(sample(logits, key, zero, tp=None, tp_size=1))
    np.testing.assert_array_equal(tok, np.asarray(logits).argmax(-1))

    # temps mix greedy + stochastic per slot in one call
    temps = jnp.asarray([0.0, 1.0] * (B // 2), jnp.float32)
    k = 4
    topk = np.argsort(np.asarray(logits), -1)[:, -k:]
    tok = np.asarray(sample(logits, key, temps, tp=None, tp_size=1,
                            cfg=SamplingConfig(top_k=k)))
    for b in range(B):
        if temps[b] == 0:
            assert tok[b] == np.asarray(logits)[b].argmax()
        else:
            assert tok[b] in topk[b]

    p = 0.5
    pr = np.asarray(jax.nn.softmax(logits.astype(jnp.float32), -1))
    order = np.argsort(-pr, -1)
    csum = np.cumsum(np.take_along_axis(pr, order, -1), -1)
    for s in range(3):
        tok = np.asarray(sample(logits, jax.random.PRNGKey(s),
                                jnp.ones(B, jnp.float32), tp=None,
                                tp_size=1, cfg=SamplingConfig(top_p=p)))
        for b in range(B):
            nucleus = set(order[b, :int((csum[b] < p).sum()) + 1])
            assert tok[b] in nucleus


# ---------------------------------------------------------------------------
# sampling statistics (tp_size == 1 in-process; tp > 1 in subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("scfg_kw", [dict(), dict(top_k=8),
                                     dict(top_p=0.6)])
def test_sampling_statistics_match_host_reference(scfg_kw):
    """Total-variation distance between >=2k fused-sampler draws and the
    host reference softmax sampler, single-device path (tp_size == 1).
    Per-slot independence turns one [DRAWS, V] batch into DRAWS
    independent draws of the same distribution."""
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import SamplingConfig, sample
    V, DRAWS, TEMP = 64, 4096, 0.7
    rng = np.random.RandomState(5)
    row = rng.randn(V) * 2.0
    logits = jnp.asarray(np.broadcast_to(row, (DRAWS, V)), jnp.float32)
    tok = np.asarray(sample(logits, jax.random.PRNGKey(11),
                            jnp.full(DRAWS, TEMP, jnp.float32),
                            tp=None, tp_size=1,
                            cfg=SamplingConfig(**scfg_kw)))
    emp = np.bincount(tok, minlength=V) / DRAWS
    ref = host_reference_probs(row, TEMP, **scfg_kw)
    tv = 0.5 * np.abs(emp - ref).sum()
    assert tv < 0.06, (scfg_kw, tv)


def test_top_p_bisection_matches_sorted_cumsum_nucleus():
    """``_apply_top_p``'s bisected probability threshold must keep
    exactly the reference nucleus (smallest top-probability set with
    mass >= p) on random logits."""
    import jax
    import jax.numpy as jnp
    from repro.serving.sampling import _apply_top_p
    B, V = 16, 128
    lt = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (B, V)),
                    np.float64) * 3.0
    for p in (0.1, 0.3, 0.6, 0.9, 0.99):
        out = np.asarray(_apply_top_p(jnp.asarray(lt, jnp.float32), p,
                                      None, 1))
        kept = np.isfinite(out)
        probs = np.exp(lt - lt.max(-1, keepdims=True))
        probs = probs / probs.sum(-1, keepdims=True)
        for b in range(B):
            order = np.argsort(-probs[b])
            csum = np.cumsum(probs[b][order])
            n_ref = int((csum < p).sum()) + 1       # minimal nucleus size
            ref = np.zeros(V, bool)
            ref[order[:n_ref]] = True
            np.testing.assert_array_equal(kept[b], ref, err_msg=f"p={p}")


# ---------------------------------------------------------------------------
# multi-device engine parity + statistics (subprocess)
# ---------------------------------------------------------------------------


def test_engine_matches_single_request_and_teacher_forced():
    """Prefill->decode parity: N-step batched engine decode (6 requests
    over 4 slots) equals the single-request run AND the teacher-forced
    forward argmax, across `none` and `spike_fused` boundary modes."""
    out = run("serving_parity")
    assert out.count("serving parity OK") == 2


def test_distributed_sampling_matches_host():
    run("serving_sampling")


@pytest.mark.slow
def test_distributed_sampling_statistics():
    """TV distance of the fused sampler vs the host reference at tp=8."""
    out = run("sampling_stats")
    assert out.count("sampling stats OK") == 3


def test_paged_pool_shared_across_mixed_lengths():
    """Block-table paging payoff: one long slot and several short ones
    share a pool SMALLER than the dense reservation, on the 2x4 mesh
    (pool pages sharded over dp x tp, slots batch-sharded over dp),
    token-identical to the dense-equivalent full pool."""
    out = run("serving_paged_mixed")
    assert "paged mixed OK" in out


def test_fused_paged_decode_parity_on_mesh():
    """Fused Pallas paged-decode vs reference dense gather on the 2x4
    mesh: 4-way compacted per-shard page lists, pool below the dense
    reservation, token-identical streams for both codecs and through
    the speculative verify path."""
    out = run("serving_fused_parity")
    assert out.count("fused parity OK") == 2


def test_speculative_decoding_parity_and_acceptance():
    """Tentpole invariant: greedy spec decoding (spec_k=3) is
    token-identical to the vanilla engine for `none` and `spike_fused`,
    accepts >1 token per verify step on a repetitive workload, uses
    fewer device steps, and leaks no pages through accept/rollback."""
    out = run("serving_spec_parity")
    assert out.count("spec parity OK") == 2


def test_speculative_recurrent_fallback():
    """Recurrent-state families force spec_k=0 and still serve."""
    run("serving_spec_recurrent_fallback")


def test_disagg_prefill_decode_parity_on_mesh():
    """Tentpole invariant: the disaggregated prefill/decode engine (dp
    group 0 prefills, group 1 decodes, KV handed over in one coded
    ppermute) is token-identical to the colocated engine for the fp and
    pow2-absmax int8 wires, for both codecs, through the async +
    speculative pipeline, and for a hybrid family whose mamba state rows
    migrate alongside the paged KV."""
    out = run("serving_disagg_parity", timeout=580)
    assert out.count("serving disagg parity OK") == 3


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2), st.integers(0, 1),
       st.sampled_from(["none", "spike_fused"]),
       st.sampled_from(["fp", "coded"]),
       st.integers(0, 2 ** 16))
def test_fuzz_disagg_matches_colocated(spec_k, async_depth, codec,
                                       kv_wire, seed):
    """Hypothesis sweep of disagg-vs-colocated greedy identity across
    spec_k x async_depth x codec x kv_wire on seed-derived random
    schedules (subprocess per draw: the 8-device mesh needs its own
    process)."""
    out = run("serving_disagg_fuzz", str(spec_k), str(async_depth),
              codec, kv_wire, str(seed), timeout=580)
    assert "disagg fuzz OK" in out
