"""parse_collectives on synthetic HLO: group sizing from replica_groups
(explicit + iota + num_partitions fallback), the unsized-group warning
that replaced the silent ``default_group=2`` guess, semantic stream
classification from ``jax.named_scope`` op_name trails, and coded-wire
detection.

Pure text parsing — no jax, no jit — so the whole file is tier-1 fast.
The compiled-HLO end-to-end counterpart (a real (2,4) mesh dry-run)
lives in tests/dist_scenarios.py::scenario_mini_dryrun.
"""
import warnings

import pytest

from repro.launch.roofline import CollectiveOp, parse_collectives

HEADER = "HloModule jit_step, num_partitions=8\n"


def _op(body):
    return HEADER + f"  {body}\n"


# ---------------------------------------------------------------------------
# group sizing
# ---------------------------------------------------------------------------


def test_explicit_replica_groups_sizes_the_ring():
    """Explicit {{...}} groups: a tp=4 all-gather prices (n-1)/n = 3/4,
    regardless of any default_group the caller passes."""
    line = ('x = f32[16]{0} all-gather(f32[4]{0} p), '
            'replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}')
    for dg in (None, 2, 16):
        st = parse_collectives(_op(line), default_group=dg)
        assert st.counts == {"all-gather": 1}
        (op,) = st.ops
        assert op.group == 4
        assert st.wire_bytes == pytest.approx(16 * 4 * 3 / 4)


def test_iota_replica_groups():
    """Iota form [num_groups,group_size]<=[N]: the SECOND number is the
    participant count."""
    line = ('x = f32[8]{0} reduce-scatter(f32[32]{0} p), '
            'replica_groups=[2,4]<=[8], dimensions={0}')
    st = parse_collectives(_op(line))
    (op,) = st.ops
    assert op.group == 4
    # reduce-scatter result f32[8] is the 32-byte shard: (n-1) * T
    assert st.wire_bytes == pytest.approx(32 * 3)


def test_empty_groups_fall_back_to_num_partitions():
    """XLA prints the all-device group as ``{}``; the module header's
    num_partitions then sizes the ring — NOT the old default of 2."""
    line = ('x = f32[8]{0} all-reduce(f32[8]{0} p), replica_groups={}, '
            'to_apply=add')
    st = parse_collectives(_op(line))
    (op,) = st.ops
    assert op.group == 8
    assert st.wire_bytes == pytest.approx(2 * 8 * 4 * 7 / 8)


def test_unsized_group_warns_and_uses_default():
    """Bug regression: no replica_groups and no num_partitions header
    used to silently assume n=2; it still falls back (so old artifacts
    parse) but now says so."""
    text = ('HloModule jit_step\n'
            '  x = f32[8]{0} all-reduce(f32[8]{0} p), to_apply=add\n')
    with pytest.warns(RuntimeWarning, match="no\n?.*replica_groups"):
        st = parse_collectives(text)
    assert st.ops[0].group == 2
    with pytest.warns(RuntimeWarning, match="group size 4"):
        st4 = parse_collectives(text, default_group=4)
    assert st4.ops[0].group == 4
    # sized ops never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        parse_collectives(_op(
            'x = f32[8]{0} all-reduce(f32[8]{0} p), replica_groups={}, '
            'to_apply=add'))


def test_permute_is_group_free():
    """collective-permute bytes are point-to-point: T, no ring factor,
    and no warning even without replica_groups."""
    line = ('x = f32[64]{0} collective-permute(f32[64]{0} p), '
            'source_target_pairs={{0,4},{4,0}}')
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        st = parse_collectives(_op(line))
    assert st.wire_bytes == pytest.approx(64 * 4)


def test_singleton_group_moves_no_bytes():
    line = ('x = f32[8]{0} all-gather(f32[8]{0} p), '
            'replica_groups={{0}}, dimensions={0}')
    st = parse_collectives(_op(line))
    assert st.wire_bytes == 0.0 and st.ops == []


# ---------------------------------------------------------------------------
# semantic streams + coded detection
# ---------------------------------------------------------------------------


def test_stream_classification_from_named_scopes():
    """op_name scope trails (repro.core.boundary's jax.named_scope) map
    collectives onto semantic streams; unlabeled ops fall back to their
    HLO kind."""
    text = HEADER + "\n".join([
        '  a = u8[8]{0} all-gather(u8[2]{0} p), replica_groups=[2,4]<=[8],'
        ' dimensions={0}, metadata={op_name="jit(step)/'
        'coded_head_all_gather/all_gather"}',
        '  b = s8[8]{0} all-gather(s8[2]{0} q), replica_groups=[2,4]<=[8],'
        ' dimensions={0}, metadata={op_name="jit(step)/'
        'coded_combine_partials/all_gather"}',
        '  c = u8[16]{0} collective-permute(u8[16]{0} r), '
        'source_target_pairs={{0,1}}, metadata={op_name="jit(step)/'
        'coded_kv_migrate/ppermute"}',
        '  d = f32[8]{0} all-reduce(f32[8]{0} s), replica_groups={}, '
        'to_apply=add, metadata={op_name="jit(step)/transformer/psum"}',
    ]) + "\n"
    st = parse_collectives(text)
    streams = {op.stream for op in st.ops}
    assert streams == {"head_all_gather", "partial_combine",
                       "kv_migrate", "psum"}
    assert set(st.by_stream) == streams
    assert sum(st.by_stream.values()) == pytest.approx(st.wire_bytes)
    by = {op.stream: op for op in st.ops}
    assert by["head_all_gather"].coded
    assert by["partial_combine"].coded
    assert by["kv_migrate"].coded
    assert not by["psum"].coded
    assert by["kv_migrate"].kind == "collective-permute"


def test_kind_fallback_streams():
    text = HEADER + "\n".join([
        '  a = f32[8]{0} all-gather(f32[2]{0} p), '
        'replica_groups=[2,4]<=[8], dimensions={0}',
        '  b = f32[8]{0} reduce-scatter(f32[32]{0} q), '
        'replica_groups=[2,4]<=[8], dimensions={0}',
    ]) + "\n"
    st = parse_collectives(text)
    assert [op.stream for op in st.ops] == ["all_gather", "psum"]


def test_tuple_result_and_coded_mix():
    """Tuple-shaped results sum every leaf; a mixed fp/int tuple is NOT
    a coded boundary."""
    line = ('x = (f32[4]{0}, s8[4]{0}) all-to-all(f32[4]{0} p, s8[4]{0} q)'
            ', replica_groups=[2,4]<=[8], dimensions={0}')
    st = parse_collectives(_op(line))
    (op,) = st.ops
    assert op.t_bytes == pytest.approx(4 * 4 + 4)
    assert not op.coded
    assert op.stream == "all_to_all"
    assert st.wire_bytes == pytest.approx((16 + 4) * 3 / 4)


def test_collective_op_is_frozen_record():
    op = CollectiveOp("all-gather", "psum", 2, 8.0, 4.0, False)
    with pytest.raises(Exception):
        op.bytes = 1.0
