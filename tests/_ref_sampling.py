"""Host-side reference sampler distribution shared by the tp=1
(tests/test_serving.py) and tp=8 (tests/dist_scenarios.py) statistical
tests — one copy of the top-k threshold / sorted-cumsum minimal-nucleus
convention, so both TV-distance checks validate against the same
reference if the fused sampler's semantics ever change."""
import numpy as np


def host_reference_probs(row, temp, top_k=0, top_p=0.0):
    """Exact next-token distribution of the reference sampler: filter
    logits on the host (top-k threshold, then smallest top-probability
    nucleus with mass >= top_p), softmax at ``temp``."""
    lt = np.asarray(row, np.float64) / temp
    if top_k:
        thr = np.sort(lt)[-top_k]
        lt = np.where(lt < thr, -np.inf, lt)
    if 0.0 < top_p < 1.0:
        p = np.exp(lt - lt[np.isfinite(lt)].max())
        p = p / p.sum()
        order = np.argsort(-p)
        keep = np.cumsum(p[order]) - p[order] < top_p   # minimal nucleus
        mask = np.zeros(lt.shape, bool)
        mask[order[keep]] = True
        lt = np.where(mask, lt, -np.inf)
    e = np.exp(lt - lt[np.isfinite(lt)].max())
    e[~np.isfinite(e)] = 0.0
    return e / e.sum()
