"""Fault-tolerance runtime: restart, NaN guard, straggler detection."""
import numpy as np
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime.ft import FTConfig, TrainLoop


class ToyStep:
    """Quadratic toy step with injectable failures."""

    def __init__(self, nan_at=(), slow_at=()):
        self.nan_at = set(nan_at)
        self.slow_at = set(slow_at)
        self.calls = 0

    def __call__(self, params, opt, batch):
        import time
        step = self.calls
        self.calls += 1
        if step in self.slow_at:
            time.sleep(0.25)
        w = params["w"]
        g = 2 * w
        new = {"w": w - 0.1 * g}
        loss = float(np.sum(np.asarray(w) ** 2))
        if step in self.nan_at:
            loss = float("nan")
        return new, opt, {"loss": jnp.asarray(loss)}


def _loop(tmp_path, step_fn, n=10, every=3):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=every,
                   async_ckpt=False)
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=4))
    return TrainLoop(step_fn, data, cfg, log_fn=lambda *_: None)


def test_restart_resumes_from_checkpoint(tmp_path):
    params = {"w": jnp.array([4.0])}
    loop = _loop(tmp_path, ToyStep(), n=10)
    p1, o1, _ = loop.run(params, {}, n_steps=7)
    # simulate crash + restart: new loop resumes from step 6 checkpoint
    loop2 = _loop(tmp_path, ToyStep())
    p2, o2, hist = loop2.run(params, {}, n_steps=10, resume=True)
    assert loop2.ckpt.latest_step() >= 9
    # resumed run only executed the remaining steps
    assert len(hist) <= 5


def test_nan_guard_skips_update(tmp_path):
    params = {"w": jnp.array([4.0])}
    loop = _loop(tmp_path, ToyStep(nan_at={2}))
    p, _, hist = loop.run(params, {}, n_steps=5, resume=False)
    assert loop.nan_skips == 1
    assert np.isfinite(float(p["w"][0]))


def test_straggler_detection(tmp_path):
    params = {"w": jnp.array([1.0])}
    loop = _loop(tmp_path, ToyStep(slow_at={5}))
    loop.run(params, {}, n_steps=8, resume=False)
    assert loop.straggler_events >= 1
