"""Fault-tolerance runtime: restart, NaN guard, straggler detection."""
import numpy as np
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime.ft import FTConfig, TrainLoop


class ToyStep:
    """Quadratic toy step with injectable failures."""

    def __init__(self, nan_at=(), slow_at=()):
        self.nan_at = set(nan_at)
        self.slow_at = set(slow_at)
        self.calls = 0

    def __call__(self, params, opt, batch):
        import time
        step = self.calls
        self.calls += 1
        if step in self.slow_at:
            time.sleep(0.25)
        w = params["w"]
        g = 2 * w
        new = {"w": w - 0.1 * g}
        loss = float(np.sum(np.asarray(w) ** 2))
        if step in self.nan_at:
            loss = float("nan")
        return new, opt, {"loss": jnp.asarray(loss)}


def _loop(tmp_path, step_fn, n=10, every=3):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=every,
                   async_ckpt=False)
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=4))
    return TrainLoop(step_fn, data, cfg, log_fn=lambda *_: None)


def test_restart_resumes_from_checkpoint(tmp_path):
    params = {"w": jnp.array([4.0])}
    loop = _loop(tmp_path, ToyStep(), n=10)
    p1, o1, _ = loop.run(params, {}, n_steps=7)
    # simulate crash + restart: new loop resumes from step 6 checkpoint
    loop2 = _loop(tmp_path, ToyStep())
    p2, o2, hist = loop2.run(params, {}, n_steps=10, resume=True)
    assert loop2.ckpt.latest_step() >= 9
    # resumed run only executed the remaining steps
    assert len(hist) <= 5


def test_nan_guard_skips_update(tmp_path):
    params = {"w": jnp.array([4.0])}
    loop = _loop(tmp_path, ToyStep(nan_at={2}))
    p, _, hist = loop.run(params, {}, n_steps=5, resume=False)
    assert loop.nan_skips == 1
    assert np.isfinite(float(p["w"][0]))


def test_straggler_detection(tmp_path):
    params = {"w": jnp.array([1.0])}
    loop = _loop(tmp_path, ToyStep(slow_at={5}))
    loop.run(params, {}, n_steps=8, resume=False)
    assert loop.straggler_events >= 1


# ---------------------------------------------------------------------------
# fault injection: the serving FaultInjector kinds mapped onto the
# training-side checkpoint/restart/straggler machinery
# ---------------------------------------------------------------------------


class ScriptedInjector:
    """Minimal ``next_fault()`` duck-type: a scripted kind per tick."""

    def __init__(self, kinds):
        self.kinds = list(kinds)
        self.injected = {"preempt": 0, "replica_loss": 0, "suspend": 0}

    def next_fault(self):
        return (self.kinds.pop(0) if self.kinds else None), 0.0


def test_injected_preempt_checkpoints_and_exits_clean(tmp_path):
    """An injected preemption notice takes the SIGTERM path: the step
    still runs, the state checkpoints, and the loop exits cleanly."""
    params = {"w": jnp.array([4.0])}
    loop = _loop(tmp_path, ToyStep())
    inj = ScriptedInjector([None, None, "preempt"])
    _, _, hist = loop.run(params, {}, n_steps=10, resume=False,
                          injector=inj)
    assert loop.preempted
    assert len(hist) == 3                      # steps 0..2 ran, then exit
    assert loop.injected == {"preempt": 1}
    assert inj.injected["preempt"] == 1        # tally mirrored
    assert loop.ckpt.latest_step() == 3        # checkpointed at exit


def test_injected_replica_loss_replays_bit_exact(tmp_path):
    """Replica loss mid-run: restore from the newest committed
    checkpoint and replay — the deterministic pipeline makes the final
    metrics history identical to an undisturbed run."""
    params = {"w": jnp.array([4.0])}
    clean = _loop(tmp_path / "clean", ToyStep())
    _, _, ref = clean.run(params, {}, n_steps=8, resume=False)

    loop = _loop(tmp_path / "faulty", ToyStep())
    # fault on tick 5: checkpoint exists at step 3 (ckpt_every=3), so
    # steps 3..4 are replayed
    inj = ScriptedInjector([None] * 5 + ["replica_loss"])
    _, _, hist = loop.run(params, {}, n_steps=8, resume=False,
                          injector=inj)
    assert loop.injected == {"replica_loss": 1}
    assert len(hist) == len(ref) == 8
    assert [h["loss"] for h in hist] == [r["loss"] for r in ref]


def test_injected_replica_loss_without_prior_checkpoint(tmp_path):
    """A fault on the very first tick restores the base checkpoint the
    injector-aware loop writes up-front (live state can't serve as the
    fallback: real train steps donate their input buffers)."""
    params = {"w": jnp.array([2.0])}
    loop = _loop(tmp_path, ToyStep())
    inj = ScriptedInjector(["replica_loss"])
    _, _, hist = loop.run(params, {}, n_steps=4, resume=False,
                          injector=inj)
    assert len(hist) == 4
    assert loop.injected == {"replica_loss": 1}


def test_injected_suspend_trips_straggler_watch(tmp_path):
    """A suspended host surfaces as wall time: the injected tick books
    an EWMA-relative delay past the straggler threshold."""
    params = {"w": jnp.array([1.0])}
    loop = _loop(tmp_path, ToyStep())
    inj = ScriptedInjector([None, None, None, "suspend"])
    loop.run(params, {}, n_steps=6, resume=False, injector=inj)
    assert loop.injected == {"suspend": 1}
    assert loop.straggler_events >= 1


def test_real_fault_injector_drives_train_loop(tmp_path):
    """The actual serving-side FaultInjector plugs straight in: one
    seeded FaultPlan drives the training stack, the budget caps the
    injections, and the tallies agree on both sides."""
    from repro.serving import FaultInjector, FaultPlan

    params = {"w": jnp.array([1.0])}
    loop = _loop(tmp_path, ToyStep())
    inj = FaultInjector(FaultPlan(seed=3, p_suspend=0.5, max_faults=2))
    loop.run(params, {}, n_steps=12, resume=False, injector=inj)
    assert 1 <= loop.injected.get("suspend", 0) <= 2
    assert loop.injected["suspend"] == inj.injected["suspend"]
    assert inj.total_injected <= 2
