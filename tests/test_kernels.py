"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional hypothesis (skips without)
from repro.core import spike
from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(8, 128), (100, 300), (256, 512),
                                   (33, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T", [7, 15])
def test_lif_encode_matches_ref(shape, dtype, T):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    theta = jnp.full((shape[1],), 0.05)
    scale = jnp.full((shape[1],), 2.0)
    out = ops.lif_encode(x, theta, scale, T=T)
    expect = ref.lif_encode_ref(x, theta, scale, T=T)
    np.testing.assert_array_equal(np.array(out), np.array(expect))


def test_lif_encode_matches_closed_form():
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    theta = jnp.full((256,), 0.02)
    scale = jnp.full((256,), 1.5)
    k = ops.lif_encode(x, theta, scale, T=15)
    cf = spike.rate_encode_signed(x, scale, theta, 15)
    assert (np.array(k) == np.array(cf).astype(np.int8)).mean() == 1.0


@pytest.mark.parametrize("mkn", [(8, 128, 128), (64, 300, 200),
                                 (256, 512, 256)])
@pytest.mark.parametrize("T", [7, 15])
def test_count_matmul_matches_ref(mkn, T):
    m, k, n = mkn
    c = jax.random.randint(jax.random.PRNGKey(0), (m, k), -T, T + 1,
                           jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (k,))) + 0.5
    y = ops.count_matmul(c, w, s, T=T, out_dtype=jnp.float32)
    ye = ref.count_matmul_ref(c, w, s, T=T, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.array(y), np.array(ye), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("shape", [(8, 128), (64, 250), (256, 1024)])
def test_pack4_roundtrip(shape):
    if shape[1] % 2:
        shape = (shape[0], shape[1] + 1)
    wire = jax.random.randint(jax.random.PRNGKey(0), shape, 0, 15,
                              jnp.uint8)
    p = ops.pack4(wire)
    assert p.shape == (shape[0], shape[1] // 2)
    np.testing.assert_array_equal(np.array(ops.unpack4(p)), np.array(wire))
    np.testing.assert_array_equal(np.array(p), np.array(ref.pack4_ref(wire)))


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 300),
       t=st.sampled_from([3, 7, 15]))
def test_lif_encode_hypothesis(rows, cols, t):
    x = jax.random.normal(jax.random.PRNGKey(rows * 1000 + cols),
                          (rows, cols))
    theta = jnp.full((cols,), 0.01)
    scale = jnp.full((cols,), 1.0)
    out = np.array(ops.lif_encode(x, theta, scale, T=t))
    expect = np.array(ref.lif_encode_ref(x, theta, scale, T=t))
    np.testing.assert_array_equal(out, expect)
    assert np.abs(out).max() <= t
