"""Host-side SLO harness tests: trace generator determinism, monitor
math under an injectable fake clock, the BENCH_serve.json schema gate,
and the step-trace -> NoC bridge files.

Everything here is pure host code — no engine, no jit — so the whole
file runs in milliseconds and belongs to the tier-1 fast lane.  The
engine-in-the-loop counterparts (fault identity, drain cleanliness)
live in tests/test_faults.py.
"""
import json
import warnings

import numpy as np
import pytest

from repro.serving import (FaultPlan, PRESETS, RequestClass, SLOMonitor,
                           SLOTargets, load_bench, make_bench_payload,
                           make_trace, preset_trace, validate_bench,
                           write_bench, zoo_mix)
from repro.serving.slo import load_trace, percentiles


# ---------------------------------------------------------------------------
# workload traces
# ---------------------------------------------------------------------------


def test_trace_seed_determinism():
    """Same seed -> identical trace (arrivals, prompts, budgets);
    different seed -> a different stream."""
    a = preset_trace("multitenant", 4.0, seed=7)
    b = preset_trace("multitenant", 4.0, seed=7)
    c = preset_trace("multitenant", 4.0, seed=8)
    assert a.requests == b.requests
    assert len(a) > 0
    assert a.requests != c.requests


def test_trace_sorted_and_budget_clamped():
    tr = preset_trace("longtail", 6.0, seed=1, prefill_len=12, max_gen=5)
    times = [r.t for r in tr.requests]
    assert times == sorted(times)
    for r in tr.requests:
        assert 0.0 <= r.t < tr.horizon_s
        assert 1 <= len(r.req.prompt) <= 12
        assert 1 <= r.req.max_new_tokens <= 5
        assert r.req.rid.split("/")[1] == r.cls


def test_trace_class_independence():
    """Adding a tenant never perturbs the existing tenants' streams
    (each class draws from its own derived seed)."""
    base = zoo_mix()
    small = make_trace(base[:2], 4.0, seed=3)
    full = make_trace(base, 4.0, seed=3)
    keep = {c.name for c in base[:2]}
    assert [r for r in full.requests if r.cls in keep] == list(small.requests)


def test_trace_fixed_prompt_len():
    tr = preset_trace("steady", 2.0, seed=0, fixed_prompt_len=9)
    assert tr.requests and all(len(r.req.prompt) == 9 for r in tr.requests)


def test_trace_validation_errors():
    with pytest.raises(ValueError):
        preset_trace("no-such-preset", 1.0)
    with pytest.raises(ValueError):
        RequestClass("bad", rate=0.0)
    with pytest.raises(ValueError):
        RequestClass("bad", rate=1.0, arrival="uniform")
    with pytest.raises(ValueError):
        RequestClass("bad", rate=1.0, prompt_len=(5, 2))
    with pytest.raises(ValueError):
        make_trace([], 1.0)


def test_presets_all_produce_arrivals():
    for name in PRESETS:
        assert len(preset_trace(name, 4.0, seed=0, load=8.0)) > 0, name


def test_lowmatch_preset_prompts_have_distinct_tokens():
    """Every lowmatch prompt is drawn without replacement: no repeated
    token means no n-gram for prompt-lookup drafting to match, which is
    the workload the learned-drafter bench compares on."""
    tr = preset_trace("lowmatch", 4.0, seed=0, prefill_len=16, max_gen=8,
                      load=8.0)
    assert len(tr) > 0
    for r in tr.requests:
        assert len(set(r.req.prompt)) == len(r.req.prompt)
    # and the prompt length still clamps to the vocab when oversized
    big = make_trace([RequestClass("lm", rate=8.0, prompt_len=(40, 40),
                                   distinct_tokens=True)],
                     2.0, seed=0, vocab=32)
    assert big.requests
    for r in big.requests:
        assert len(r.req.prompt) == 32
        assert len(set(r.req.prompt)) == 32


# ---------------------------------------------------------------------------
# monitor math (fake clock, stub engine)
# ---------------------------------------------------------------------------


class _Clock:
    """Injectable monotonic clock: ``clk.t = ...`` then the monitor
    reads exactly that."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class _StubAlloc:
    pages_in_use = 3
    pages_in_limbo = 1


class _StubCache:
    allocator = _StubAlloc()


class _StubEngine:
    spec_k = 0
    cache = _StubCache()

    def __init__(self):
        self.tokens_generated = 0
        self.decode_steps = 0
        self.queue_depth = 0
        self.num_active = 1


def test_percentiles_empty_and_known():
    assert percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                               "mean": 0.0, "n": 0}
    p = percentiles(range(1, 101))
    assert p["n"] == 100 and p["mean"] == 50.5
    assert p["p50"] == pytest.approx(50.5)
    assert p["p99"] == pytest.approx(np.percentile(range(1, 101), 99))


def test_monitor_ttft_tpot_attainment_math():
    """Hand-driven lifecycle on a fake clock: TTFT/TPOT come out exact,
    and attainment judges each request against the targets."""
    clk = _Clock()
    mon = SLOMonitor(targets=SLOTargets(ttft_ms=100.0, tpot_ms=10.0),
                     clock=clk)
    # r0: TTFT 50ms (ok), 5 tokens over 20ms -> TPOT 5ms (ok)
    mon.on_submit("r0", 8)
    clk.t = 0.050
    mon.on_first_token("r0")
    clk.t = 0.070
    mon.on_finish("r0", 5)
    # r1: TTFT 200ms (violates), 3 tokens at 4ms/tok (ok)
    clk.t = 0.0
    mon.on_submit("r1", 4)
    clk.t = 0.200
    mon.on_first_token("r1")
    clk.t = 0.208
    mon.on_finish("r1", 3)
    rep = mon.report()
    assert rep["requests"] == {"submitted": 2, "finished": 2, "restarts": 0}
    assert rep["ttft_ms"]["p50"] == pytest.approx(125.0)
    assert rep["ttft_ms"]["mean"] == pytest.approx(125.0)
    assert rep["tpot_ms"]["n"] == 2
    assert rep["tpot_ms"]["mean"] == pytest.approx((5.0 + 4.0) / 2)
    slo = rep["slo"]
    assert slo["ttft_attainment"] == 0.5
    assert slo["tpot_attainment"] == 1.0
    assert slo["attainment"] == 0.5


def test_monitor_restart_keeps_original_submit_clock():
    """A preempted request restarts from scratch but its TTFT keeps
    measuring from the ORIGINAL submit — the re-queue penalty is the
    SLO story."""
    clk = _Clock()
    mon = SLOMonitor(clock=clk)
    mon.on_submit("r0", 8)
    clk.t = 0.010
    mon.on_first_token("r0")
    clk.t = 0.020
    mon.on_preempt("r0", "pool_pressure")
    mon.on_submit("r0", 8)             # engine re-admits from the queue
    clk.t = 0.300
    mon.on_first_token("r0")
    clk.t = 0.350
    mon.on_finish("r0", 4)
    rep = mon.report()
    assert rep["requests"]["restarts"] == 2   # preempt + resubmit
    assert rep["faults"]["preemptions"] == 1
    assert rep["ttft_ms"]["mean"] == pytest.approx(300.0)


def test_monitor_suspend_resets_inflight_records():
    clk = _Clock()
    mon = SLOMonitor(clock=clk)
    mon.on_submit("a", 4)
    mon.on_submit("b", 4)
    clk.t = 0.010
    mon.on_first_token("a")
    mon.on_suspend(["a"])              # b was still queued: untouched
    assert mon.suspends == 1
    assert mon.requests["a"].t_first is None
    assert mon.requests["a"].restarts == 1
    assert mon.requests["b"].restarts == 0
    clk.t = 0.050
    mon.on_first_token("a")            # re-measures after the restart
    assert mon.requests["a"].t_first == pytest.approx(0.050)


def test_monitor_step_trace_and_wire_bytes():
    """on_step snapshots queue/pool state and prices wire bytes per
    DEVICE step (a tick that commits two async steps carries 2x)."""
    clk = _Clock()
    eng = _StubEngine()
    mon = SLOMonitor(wire_bytes_per_step={"decode": 100.0}, clock=clk)
    eng.decode_steps, eng.tokens_generated, eng.queue_depth = 1, 3, 5
    mon.on_step(eng)
    clk.t = 0.001
    eng.decode_steps, eng.tokens_generated = 3, 9   # 2 steps this tick
    mon.on_step(eng)
    trace = mon.step_trace()
    assert [s["wire_bytes"] for s in trace] == [100.0, 200.0]
    assert [s["tokens"] for s in trace] == [3, 6]
    assert trace[1]["dt_us"] == pytest.approx(1000.0)
    assert trace[0]["queue_depth"] == 5
    assert trace[0]["pages_in_use"] == 3
    rep = mon.report()
    assert rep["queue_depth"]["max"] == 5
    assert rep["pool"]["peak_pages_in_limbo"] == 1


def test_monitor_wire_streams_split_and_scaling():
    """A registered stream profile lands a per-collective breakdown in
    every StepEvent, scaled per DEVICE step, and always summing to the
    scalar wire_bytes; migration bytes appear as a kv_migrate stream."""
    clk = _Clock()
    eng = _StubEngine()
    mon = SLOMonitor(clock=clk, wire_streams_per_step={
        "decode": {"psum": 60.0, "head_all_gather": 40.0}})
    # scalar derived from the stream sums, no separate registration
    assert mon.wire_bytes_per_step == {"decode": 100.0}
    eng.decode_steps, eng.tokens_generated = 1, 2
    mon.on_step(eng)
    clk.t = 0.001
    eng.decode_steps, eng.tokens_generated = 3, 6   # 2 steps this tick
    mon.on_migrate("r0", 0, 1, 25)
    mon.on_step(eng)
    trace = mon.step_trace()
    assert trace[0]["wire_streams"] == {"psum": 60.0,
                                        "head_all_gather": 40.0}
    assert trace[1]["wire_streams"] == {"psum": 120.0,
                                        "head_all_gather": 80.0,
                                        "kv_migrate": 25.0}
    for s in trace:
        assert sum(s["wire_streams"].values()) == pytest.approx(
            s["wire_bytes"])


def test_monitor_scalar_only_falls_back_to_total_stream():
    """Callers without a stream profile still get a priceable trace:
    the scalar is recorded as one 'total' stream."""
    clk = _Clock()
    eng = _StubEngine()
    mon = SLOMonitor(wire_bytes_per_step={"decode": 64.0}, clock=clk)
    eng.decode_steps = 1
    mon.on_step(eng)
    assert mon.step_trace()[0]["wire_streams"] == {"total": 64.0}


def test_monitor_warns_on_unknown_step_kind():
    """Bug regression: an incomplete pricing table used to silently
    record 0 wire bytes for unregistered step kinds.  Now a mixed-kind
    trace warns once per unknown kind (and never for registered ones or
    when no pricing was registered at all)."""

    class _SpecEngine(_StubEngine):
        spec_k = 2                       # ticks are kind="verify"

    clk = _Clock()
    eng = _SpecEngine()
    # "verify" missing from the registered table -> warn
    mon = SLOMonitor(wire_bytes_per_step={"decode": 100.0}, clock=clk)
    eng.decode_steps = 1
    with pytest.warns(RuntimeWarning, match="verify"):
        mon.on_step(eng)
    # ...but only once per kind
    clk.t = 0.001
    eng.decode_steps = 2
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mon.on_step(eng)
    # a registered kind never warns
    mon2 = SLOMonitor(wire_bytes_per_step={"verify": 10.0}, clock=_Clock())
    eng2 = _SpecEngine()
    eng2.decode_steps = 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mon2.on_step(eng2)
    # an unpriced monitor (no table at all) stays silent too
    mon3 = SLOMonitor(clock=_Clock())
    eng3 = _SpecEngine()
    eng3.decode_steps = 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mon3.on_step(eng3)


def test_monitor_flushes_migration_on_last_tick():
    """Bug regression: migration bytes arriving after the LAST tick
    (admission at drain) used to be dropped from wire accounting.  They
    now flush into a terminal dt=0 'drain' event, exactly once."""
    clk = _Clock()
    eng = _StubEngine()
    mon = SLOMonitor(wire_bytes_per_step={"decode": 10.0}, clock=clk)
    eng.decode_steps, eng.queue_depth = 1, 2
    mon.on_step(eng)
    mon.on_migrate("r9", 0, 1, 500)      # no further on_step
    trace = mon.step_trace()
    assert len(trace) == 2
    drain = trace[-1]
    assert drain["kind"] == "drain"
    assert drain["dt_us"] == 0.0
    assert drain["tokens"] == 0
    assert drain["wire_bytes"] == 500.0
    assert drain["mig_bytes"] == 500.0
    assert drain["wire_streams"] == {"kv_migrate": 500.0}
    assert drain["queue_depth"] == 2     # context copied from last tick
    # total wire bytes conserved: 10 (step) + 500 (migration)
    assert sum(s["wire_bytes"] for s in trace) == pytest.approx(510.0)
    # flush is idempotent: report() + another step_trace() add nothing
    rep = mon.report()
    assert rep["migration"]["kb_total"] == pytest.approx(0.5)
    assert len(mon.step_trace()) == 2
    # dt=0 keeps the drain event out of the step-latency percentiles
    assert rep["step_us"]["n"] == 0


def test_monitor_flush_without_pending_is_noop():
    mon = SLOMonitor(clock=_Clock())
    eng = _StubEngine()
    mon.on_step(eng)
    assert len(mon.step_trace()) == 1
    mon.report()
    assert len(mon.step_trace()) == 1


def test_monitor_acceptance_math():
    """Accepted-draft length is the per-tick delta of the engine's
    commit/verify counters; the report's rate strips the always-kept
    correction token and normalises by spec_k."""

    class _SpecEngine(_StubEngine):
        spec_k = 2

        def __init__(self):
            super().__init__()
            self.spec_commits = 0
            self.spec_verifies = 0

    clk = _Clock()
    eng = _SpecEngine()
    mon = SLOMonitor(clock=clk)
    # tick 1: 3 verifies committed 6 tokens -> accepted_len 2.0
    eng.spec_commits, eng.spec_verifies = 6, 3
    mon.on_step(eng)
    # tick 2: +2 verifies, +6 tokens -> accepted_len 3.0
    clk.t = 0.001
    eng.spec_commits, eng.spec_verifies = 12, 5
    mon.on_step(eng)
    # tick 3: no verify participation -> not a speculative tick
    clk.t = 0.002
    mon.on_step(eng)
    assert [s["accepted_len"] for s in mon.step_trace()] == [2.0, 3.0, 0.0]
    acc = mon.report()["acceptance"]
    assert acc["accepted_len"]["n"] == 2
    assert acc["accepted_len"]["mean"] == pytest.approx(2.5)
    # mean accepted 2.5 = 1 correction + 1.5 of the 2 drafts kept
    assert acc["rate"] == pytest.approx(0.75)


def test_monitor_acceptance_zero_on_nonspec_runs():
    """A non-speculative engine (and host-side stubs without the spec
    counters at all) reports an all-zero acceptance block."""
    mon = SLOMonitor(clock=_Clock())
    mon.on_step(_StubEngine())
    acc = mon.report()["acceptance"]
    assert acc["rate"] == 0.0
    assert acc["accepted_len"]["n"] == 0


def test_write_trace_roundtrip(tmp_path):
    clk = _Clock()
    mon = SLOMonitor(wire_bytes_per_step={"decode": 64.0}, clock=clk)
    eng = _StubEngine()
    for i in range(3):
        clk.t = i * 0.002
        eng.decode_steps, eng.tokens_generated = i + 1, (i + 1) * 2
        mon.on_step(eng)
    path = tmp_path / "steps.jsonl"
    mon.write_trace(str(path))
    back = load_trace(str(path))
    assert back == mon.step_trace()


# ---------------------------------------------------------------------------
# fault plan validation
# ---------------------------------------------------------------------------


def test_fault_plan_probability_sum_validated():
    FaultPlan(p_preempt=0.5, p_replica_loss=0.3, p_suspend=0.2)
    with pytest.raises(ValueError):
        FaultPlan(p_preempt=0.6, p_replica_loss=0.3, p_suspend=0.2)


# ---------------------------------------------------------------------------
# BENCH_serve.json schema
# ---------------------------------------------------------------------------


def _result():
    pctl = {"p50": 1.0, "p95": 2.0, "p99": 3.0, "mean": 1.5, "n": 4}
    return {"tokens_per_s": 100.0, "wire_kb_per_tok": 1.5,
            "step_us": dict(pctl), "ttft_ms": dict(pctl),
            "tpot_ms": dict(pctl),
            "slo": {"ttft_target_ms": 500.0, "tpot_target_ms": 100.0,
                    "ttft_attainment": 1.0, "tpot_attainment": 1.0,
                    "attainment": 1.0},
            "faults": {"preemptions": 0, "suspends": 0}}


def test_bench_payload_roundtrip(tmp_path):
    payload = make_bench_payload({"bench": "t", "mesh": "1x1"},
                                 {"spike_fused": _result()})
    path = tmp_path / "BENCH_serve.json"
    write_bench(str(path), payload)
    assert load_bench(str(path)) == payload
    # stable output: keys sorted, trailing newline
    text = path.read_text()
    assert text.endswith("\n")
    assert json.loads(text) == payload


def test_bench_schema_rejects_bad_payloads(tmp_path):
    good = make_bench_payload({"bench": "t"}, {"none": _result()})
    with pytest.raises(ValueError):
        validate_bench({**good, "schema": "bench_serve/v0"})
    with pytest.raises(ValueError):
        validate_bench({**good, "run": {}})
    with pytest.raises(ValueError):
        validate_bench({**good, "results": {}})
    r = _result()
    del r["ttft_ms"]["p99"]
    with pytest.raises(ValueError):
        validate_bench({**good, "results": {"none": r}})
    r = _result()
    r["slo"]["attainment"] = 1.5
    with pytest.raises(ValueError):
        validate_bench({**good, "results": {"none": r}})
    r = _result()
    del r["faults"]
    with pytest.raises(ValueError):
        validate_bench({**good, "results": {"none": r}})
    # load_bench is the CI gate: a corrupt file on disk must raise too
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "bench_serve/v1", "run": {"x": 1},
                               "results": {"none": {}}}))
    with pytest.raises(ValueError):
        load_bench(str(bad))


def _cosim(noc_cpt=1500.0, emio_cpt=1200.0):
    return {"joules_per_token": 1e-9, "noc_cycles_per_token": noc_cpt,
            "noc_us_per_token": noc_cpt / 200.0,
            "emio_closed_form_cycles_per_token": emio_cpt,
            "energy_breakdown": {"PE": 1.0, "MEM": 2.0, "Router": 3.0,
                                 "EMIO": 4.0}}


def test_bench_schema_cosim_block():
    """The optional per-codec cosim block is schema-gated: required
    keys, an energy breakdown, and the cycle-level >= closed-form EMIO
    invariant."""
    res = {**_result(), "cosim": _cosim()}
    make_bench_payload({"bench": "t", "cosim": True}, {"none": res})
    # a result WITHOUT the block still validates (cosim is opt-in)
    make_bench_payload({"bench": "t"}, {"none": _result()})
    # missing required key
    r = {**_result(), "cosim": _cosim()}
    del r["cosim"]["noc_us_per_token"]
    with pytest.raises(ValueError):
        make_bench_payload({"bench": "t"}, {"none": r})
    # missing energy component
    r = {**_result(), "cosim": _cosim()}
    del r["cosim"]["energy_breakdown"]["Router"]
    with pytest.raises(ValueError):
        make_bench_payload({"bench": "t"}, {"none": r})
    # cycle-level simulation must bound the closed-form figure above
    r = {**_result(), "cosim": _cosim(noc_cpt=1000.0, emio_cpt=1200.0)}
    with pytest.raises(ValueError, match="upper-bound"):
        make_bench_payload({"bench": "t"}, {"none": r})
    # equality (both zero, e.g. a 1x1 mesh) is fine
    r = {**_result(), "cosim": _cosim(noc_cpt=0.0, emio_cpt=0.0)}
    make_bench_payload({"bench": "t"}, {"none": r})
