"""Randomized-schedule fuzz of the serving engine (single device).

Property: for ANY schedule — mixed prompt lengths, per-request
``max_new_tokens``, eos hits, queue pressure beyond the slot pool — every
request's greedy output equals a solo run of the same request (batch
composition can never leak between slots), for both the vanilla engine
and the speculative one, and the page allocator ends every run with all
pages free (no slot/page leaks through admit/retire/accept/rollback).

Async pipeline property (``async_depth=1``): the SAME schedules through
the dispatch/commit pipeline — step t+1 dispatched before step t's
tokens are synced, retirement/rollback/admission bookkeeping deferred
one step, freed pages parked in the deferred-free limbo — are
token-identical to the synchronous engine, for vanilla and speculative
decoding and for the ``none`` and ``spike_fused`` codecs, and every run
still drains slot- and page-clean (nothing leaks through the limbo).

Runs under hypothesis when installed (``pip install -e .[dev]``); without
it the ``@given`` property pytest-skips (tests/_hyp.py) and the fixed
deterministic schedules below still exercise the same invariants.

Engines are built once per module (compile cost) and reused across
schedules: a drained engine is a clean engine — that reuse is itself part
of the property.
"""
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st

PREFILL_LEN = 16
MAX_SEQ = 32
NUM_SLOTS = 3
VOCAB = 256
EOS = 7

_ENGINES = None
_ASYNC_ENGINES = {}
_HEADS_ENGINES = {}
_MODELS = {}


def _engine_kw():
    return dict(num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
                prefill_len=PREFILL_LEN, page_size=8, eos_id=EOS)


def _model(codec):
    """(cfg, mesh, params) for one codec — ONE param init shared by
    every engine fixture of that codec."""
    if codec not in _MODELS:
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.configs.reduced import reduced
        from repro.launch import specs as SP, train as TR
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
            dtype=jnp.float32, codec=codec)
        cell = ShapeCell("serve_decode", MAX_SEQ, NUM_SLOTS, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        _MODELS[codec] = (cfg, mesh, params)
    return _MODELS[codec]


def _build_engine(codec, **extra):
    from repro.serving import EngineConfig, ServingEngine
    cfg, mesh, params = _model(codec)
    return ServingEngine(cfg, mesh, params,
                         EngineConfig(**_engine_kw(), **extra))


def _engines():
    """(cfg, batched vanilla, batched spec_k=2, solo) — built lazily once."""
    global _ENGINES
    if _ENGINES is None:
        _ENGINES = (_model("none")[0], _build_engine("none"),
                    _build_engine("none", spec_k=2), _build_engine("none"))
    return _ENGINES


def _async_engines(codec):
    """(sync ref, async_depth=1 vanilla, async_depth=1 spec_k=2) — lazily
    built once per codec and reused across schedules."""
    if codec not in _ASYNC_ENGINES:
        if codec == "none":
            sync = _engines()[1]          # share the module's sync engine
        else:
            sync = _build_engine(codec)
        _ASYNC_ENGINES[codec] = (
            sync,
            _build_engine(codec, async_depth=1),
            _build_engine(codec, async_depth=1, spec_k=2))
    return _ASYNC_ENGINES[codec]


def _heads_engines(codec):
    """(sync ref, heads spec_k=2 sync, heads spec_k=2 async_depth=1) —
    lazily built once per codec.  The heads are RANDOM (w2 perturbed
    away from the identity init): their drafts are deliberately
    arbitrary, because greedy token identity must hold for ANY draft
    content — random heads stress the reject/rollback path the way
    trained heads never would."""
    if codec not in _HEADS_ENGINES:
        import jax
        from repro.launch import train as TR
        from repro.launch.mesh import make_mesh  # noqa: F401 (same jax)
        from repro.launch.specs import make_plan
        from repro.configs.base import ShapeCell
        from repro.serving import EngineConfig, ServingEngine

        cfg, mesh, params = _model(codec)
        plan = make_plan(cfg, ShapeCell("serve_decode", MAX_SEQ,
                                        NUM_SLOTS, "decode"), mesh)
        hp = TR.init_draft_head_params(cfg, plan, mesh,
                                       jax.random.PRNGKey(5), 2)
        hp = dict(hp)
        hp["w2"] = 0.3 * jax.random.normal(jax.random.PRNGKey(6),
                                           hp["w2"].shape, hp["w2"].dtype)
        full = dict(params)
        full["draft_heads"] = hp
        kw = dict(spec_k=2, drafter="heads")
        _HEADS_ENGINES[codec] = (
            _engines()[1] if codec == "none" else _build_engine(codec),
            ServingEngine(cfg, mesh, full,
                          EngineConfig(**_engine_kw(), **kw)),
            ServingEngine(cfg, mesh, full,
                          EngineConfig(**_engine_kw(), **kw,
                                       async_depth=1)))
    return _HEADS_ENGINES[codec]


def _check_heads_schedule(schedule, codec):
    """Heads-drafter parity leg: the same schedule through the sync
    vanilla engine, the heads verify engine, and the heads verify
    engine under the async pipeline — greedy streams identical even
    though the (random) heads propose garbage, and all three drain
    clean.  The ngram drafter can never pipeline (it needs committed
    tokens on the host), so its counter staying zero is the structural
    no-host-join assertion's other half."""
    from repro.serving import Request
    ref_eng, heads, heads_async = _heads_engines(codec)
    rng = np.random.RandomState(97)
    reqs = [Request(rid=i, prompt=list(rng.randint(0, VOCAB, plen)),
                    max_new_tokens=mnt)
            for i, (plen, mnt) in enumerate(schedule)]

    def clone(r):
        return Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)

    ref = ref_eng.run([clone(r) for r in reqs])
    res_h = heads.run([clone(r) for r in reqs])
    res_ha = heads_async.run([clone(r) for r in reqs])
    assert set(ref) == set(res_h) == set(res_ha)
    for r in reqs:
        assert res_h[r.rid] == ref[r.rid], (
            "heads", codec, r.rid, ref[r.rid], res_h[r.rid])
        assert res_ha[r.rid] == ref[r.rid], (
            "heads+async", codec, r.rid, ref[r.rid], res_ha[r.rid])
    for e in (ref_eng, heads, heads_async):
        _assert_drained(e)
    # a synchronous heads engine never overlaps dispatches
    assert heads.pipelined_dispatches == 0


def _assert_drained(engine):
    alloc = engine.cache.allocator
    assert engine.idle
    assert not engine._inflight, "uncommitted dispatched step"
    assert alloc._dispatched == alloc._committed, "unbalanced epochs"
    assert alloc.num_free == NUM_SLOTS, "slot leak"
    assert alloc.pages_in_use == 0, "page leak"
    assert alloc.pages_in_limbo == 0, "page stuck in deferred-free limbo"
    assert (alloc._len == 0).all(), "stale occupancy"
    assert (alloc.block_table == -1).all(), "stale block-table mapping"


def _check_schedule(schedule):
    """schedule: list of (prompt_len, max_new_tokens) pairs."""
    from repro.serving import Request
    _, batched, spec, solo = _engines()
    rng = np.random.RandomState(1234)
    reqs = [Request(rid=i, prompt=list(rng.randint(0, VOCAB, plen)),
                    max_new_tokens=mnt)
            for i, (plen, mnt) in enumerate(schedule)]

    def clone(r):
        return Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)

    res = batched.run([clone(r) for r in reqs])
    res_spec = spec.run([clone(r) for r in reqs])
    assert set(res) == {r.rid for r in reqs}
    for r in reqs:
        ref = solo.run([clone(r)])[r.rid]
        assert res[r.rid] == ref, (r.rid, ref, res[r.rid])
        assert res_spec[r.rid] == ref, ("spec", r.rid, ref, res_spec[r.rid])
        # output contract: exactly max_new_tokens unless eos cut it short
        if len(ref) < r.max_new_tokens:
            assert ref[-1] == EOS
        _assert_drained(solo)
    _assert_drained(batched)
    _assert_drained(spec)


def _check_async_schedule(schedule, codec):
    """Async (``async_depth=1``) vs sync token parity on one schedule:
    same requests through the synchronous engine, the pipelined vanilla
    engine, and the pipelined speculative engine (``spec_k=2``) — every
    rid's greedy stream must be identical, and all three must drain
    slot-, page- and limbo-clean."""
    from repro.serving import Request
    sync, asn, asn_spec = _async_engines(codec)
    rng = np.random.RandomState(4321)
    reqs = [Request(rid=i, prompt=list(rng.randint(0, VOCAB, plen)),
                    max_new_tokens=mnt)
            for i, (plen, mnt) in enumerate(schedule)]

    def clone(r):
        return Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)

    ref = sync.run([clone(r) for r in reqs])
    res_a = asn.run([clone(r) for r in reqs])
    res_s = asn_spec.run([clone(r) for r in reqs])
    assert set(res_a) == set(ref) == set(res_s)
    for r in reqs:
        assert res_a[r.rid] == ref[r.rid], (
            codec, r.rid, ref[r.rid], res_a[r.rid])
        assert res_s[r.rid] == ref[r.rid], (
            "spec", codec, r.rid, ref[r.rid], res_s[r.rid])
    for e in (sync, asn, asn_spec):
        _assert_drained(e)


# ---------------------------------------------------------------------------
# fixed deterministic schedules (always run, no hypothesis needed)
# ---------------------------------------------------------------------------


def test_fixed_schedule_queue_pressure():
    """7 mixed-length requests through 3 slots: admits interleave with
    retirements and the queue drains in arrival order."""
    _check_schedule([(16, 6), (3, 1), (16, 8), (1, 4), (9, 8), (16, 2),
                     (5, 5)])


def test_fixed_schedule_single_and_short():
    _check_schedule([(1, 1)])
    _check_schedule([(16, 12), (16, 12), (16, 12)])


def test_fixed_schedule_async_parity_queue_pressure():
    """Async pipeline (depth 1) vs sync on the queue-pressure schedule:
    mid-flight admits, late-EOS zombie steps, deferred retirement — all
    token-identical, slot/page/limbo-clean."""
    _check_async_schedule([(16, 6), (3, 1), (16, 8), (1, 4), (9, 8),
                           (16, 2), (5, 5)], "none")
    _check_async_schedule([(1, 1)], "none")


def test_fixed_schedule_heads_drafter_parity():
    """Random draft heads through sync + pipelined verify on the
    queue-pressure schedule: token-identical to vanilla, drain-clean,
    and the async heads engine actually overlapped verify dispatches
    (the no-host-join acceptance assertion) while the ngram engine's
    counter stayed a structural zero."""
    _, heads, heads_async = _heads_engines("none")
    base = heads_async.pipelined_dispatches
    _check_heads_schedule([(16, 6), (3, 1), (16, 8), (1, 4), (9, 8),
                           (16, 2), (5, 5)], "none")
    assert heads_async.pipelined_dispatches > base, \
        "async heads engine never pipelined a verify dispatch"
    # the ngram spec engine on the same module: drafting host-side
    # forces a join per verify step, so it can never overlap
    _, _, asn_spec = _async_engines("none")
    assert asn_spec.pipelined_dispatches == 0


def test_fixed_schedule_heads_drafter_parity_spike_codec():
    _check_heads_schedule([(16, 6), (3, 1), (16, 8), (1, 4)],
                          "spike_fused")


def test_async_warmup_and_reset_stats_flush_inflight():
    """``warmup``/``reset_stats`` must drain the pipeline before zeroing
    stats: a pipelined step's tokens can never leak into the measured
    run, and a mid-flight reset loses no results."""
    from repro.serving import Request
    _, asn, _ = _async_engines("none")
    asn.warmup([1, 2, 3, 4])
    assert asn.tokens_generated == 0 and asn.decode_steps == 0
    assert not asn._inflight
    # the throwaway admission must not contaminate the measured pool
    # high-water mark either
    assert asn.cache.peak_pages_in_use == 0
    # dispatch without committing, then reset: the in-flight step is
    # committed (not dropped) and the request still completes exactly
    asn.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=6))
    assert asn.dispatch() is True and len(asn._inflight) == 1
    asn.reset_stats()
    assert not asn._inflight and asn.tokens_generated == 0
    res = asn.run([])
    assert len(res[0]) == 6 or res[0][-1] == EOS
    _assert_drained(asn)


def test_async_depth_validation_is_typed():
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.reduced import reduced
    from repro.launch.mesh import make_mesh
    from repro.serving import EngineConfig, EngineConfigError, ServingEngine
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, {}, EngineConfig(num_slots=2, max_seq=32,
                                                  async_depth=-1))


# ---------------------------------------------------------------------------
# hypothesis property (skips cleanly when hypothesis is not installed)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(1, PREFILL_LEN),
                          st.integers(1, 8)),
                min_size=1, max_size=2 * NUM_SLOTS + 1))
def test_fuzz_schedules_match_solo_and_leak_free(schedule):
    _check_schedule(schedule)


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.integers(1, PREFILL_LEN),
                          st.integers(1, 8)),
                min_size=1, max_size=2 * NUM_SLOTS + 1),
       st.sampled_from(["none", "spike_fused"]))
def test_fuzz_async_parity_and_no_leaks(schedule, codec):
    """Randomized schedules through the async pipeline: ``async_depth=1``
    (vanilla and ``spec_k=2``) must be token-identical to the sync
    engine for the ``none`` AND ``spike_fused`` codecs, with no slot or
    page leaked through deferred retirement / the free-page limbo."""
    _check_async_schedule(schedule, codec)


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.integers(1, PREFILL_LEN),
                          st.integers(1, 8)),
                min_size=1, max_size=2 * NUM_SLOTS + 1),
       st.sampled_from(["none", "spike_fused"]))
def test_fuzz_heads_drafter_parity_and_no_leaks(schedule, codec):
    """The drafter leg of the identity grid: RANDOM draft heads (their
    proposals are garbage by construction) through the device-chained
    heads verify path, sync and pipelined, must stay greedy
    token-identical to vanilla decode on ANY schedule — the drafter
    moves which positions get scored per forward, never what commits —
    and every run drains slot/page/limbo-clean."""
    _check_heads_schedule(schedule, codec)


# ---------------------------------------------------------------------------
# block-table paging: O(page_size) admits + typed pool exhaustion
# ---------------------------------------------------------------------------


def test_admit_maps_prompt_pages_not_max_seq():
    """Acceptance: admitting a ``prompt_len == page_size`` request maps
    O(page_size) KV bytes — ONE page — while the old slot-major layout
    charged the slot its full ``max_seq`` reservation up front."""
    from repro.serving import Request
    _, batched, _, _ = _engines()
    cache = batched.cache
    page_size = cache.allocator.page_size
    batched._admit(Request(rid=0, prompt=list(range(1, page_size + 1)),
                           max_new_tokens=4))
    assert not batched._retired
    assert cache.allocator.pages_in_use == 1
    assert cache.kv_bytes_mapped() == cache.kv_page_bytes() > 0
    # dense reservation would have charged pages_per_slot pages NOW
    dense_slot = cache.allocator.pages_per_slot * cache.kv_page_bytes()
    assert cache.kv_bytes_mapped() * cache.allocator.pages_per_slot \
        == dense_slot
    assert cache.kv_bytes_mapped() < dense_slot
    # drain so the module-shared engine stays clean for other tests
    while not batched.idle:
        batched.step()
    _assert_drained(batched)


def test_hybrid_family_mixes_paged_kv_and_slot_major_state():
    """A hybrid (attention + mamba) cache tree carries pool-shaped KV
    leaves and slot-major state leaves through the same insert/decode/
    evict cycle: only attention KV is paged, recurrent state stays
    slot-major, and the engine still drains page-clean."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.serving import EngineConfig, Request, ServingEngine

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("jamba-1.5-large-398b",
                             hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    params = TR.init_sharded_params(
        cfg, SP.make_plan(cfg, ShapeCell("serve_decode", 32, 2, "decode"),
                          mesh), mesh, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        num_slots=2, max_seq=32, prefill_len=16, page_size=8))
    # pool leaves exist (attn layers) AND slot-major state leaves exist
    assert eng.cache.kv_page_bytes() > 0
    assert eng.cache.state_bytes_per_slot() > 0
    rng = np.random.RandomState(0)
    res = eng.run([Request(rid=i, prompt=list(rng.randint(0, 256, 16)),
                           max_new_tokens=6) for i in range(3)])
    assert len(res) == 3 and all(len(v) == 6 for v in res.values())
    alloc = eng.cache.allocator
    assert alloc.pages_in_use == 0 and (alloc.block_table == -1).all()


_JAMBA = None


def _jamba_engine():
    """Cached hybrid-family (attention + mamba) engine on a (1, 1) mesh."""
    global _JAMBA
    if _JAMBA is None:
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.configs.reduced import reduced
        from repro.launch import specs as SP, train as TR
        from repro.launch.mesh import make_mesh
        from repro.serving import EngineConfig, ServingEngine

        mesh = make_mesh((1, 1), ("data", "model"))
        cfg = reduced(get_config("jamba-1.5-large-398b",
                                 hnn_mode="ann")).replace(
            dtype=jnp.float32, codec="none")
        params = TR.init_sharded_params(
            cfg, SP.make_plan(cfg, ShapeCell("serve_decode", 32, 2,
                                             "decode"), mesh),
            mesh, jax.random.PRNGKey(0))
        _JAMBA = ServingEngine(cfg, mesh, params, EngineConfig(
            num_slots=2, max_seq=32, prefill_len=16, page_size=8))
    return _JAMBA


def test_recurrent_short_prompts_use_exact_length_buckets():
    """Regression for the prefill-length bug (PR-8): recurrent-state
    families used to reject any prompt whose length differed from
    ``prefill_len`` (right-padding a recurrent scan corrupts the carried
    state, so the engine demanded exact length — and short prompts were
    simply inadmissible).  The fix prefills through lazily compiled
    exact-length buckets: any ``prompt_len % tp_size == 0`` admits, each
    distinct length compiles once, and outputs are batch-composition
    independent."""
    from repro.serving import Request
    eng = _jamba_engine()
    assert eng.cache.state_bytes_per_slot() > 0    # really recurrent
    rng = np.random.RandomState(7)
    lens = [4, 10, 16, 4]          # pre-fix: ValueError for 4 and 10
    reqs = [Request(rid=i, prompt=list(rng.randint(0, VOCAB, n)),
                    max_new_tokens=5) for i, n in enumerate(lens)]

    def clone(r):
        return Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)

    res = eng.run([clone(r) for r in reqs])
    assert set(res) == set(range(len(lens)))
    # one bucket per distinct length (16 is the eagerly built default);
    # a repeated length recompiles nothing
    assert set(eng._prefill_buckets) == {4, 10, 16}
    # batch composition cannot leak: solo runs reuse the cached buckets
    # and must reproduce the batched streams token for token
    for r in reqs:
        assert eng.run([clone(r)])[r.rid] == res[r.rid], r.rid
    alloc = eng.cache.allocator
    assert alloc.pages_in_use == 0 and alloc.pages_in_limbo == 0
    assert (alloc.block_table == -1).all()


def test_page_pool_exhaustion_is_typed_and_pool_bound():
    """``PagePoolExhausted`` fires when (and only when) the PAGE POOL is
    the binding limit: slots are still free, but a live slot's growth
    has no page left to map.  Built on a deliberately undersized pool
    (3 pages < pages_per_slot * num_slots = 12)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.serving import (EngineConfig, PagePoolExhausted, Request,
                               ServingEngine)

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    params = TR.init_sharded_params(
        cfg, SP.make_plan(cfg, ShapeCell("serve_decode", MAX_SEQ,
                                         NUM_SLOTS, "decode"), mesh),
        mesh, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        num_slots=NUM_SLOTS, max_seq=MAX_SEQ, prefill_len=PREFILL_LEN,
        page_size=8, num_pages=1))
    # a prompt that could NEVER fit the 1-page pool is refused at submit
    with pytest.raises(ValueError):
        eng.submit(Request(rid=9, prompt=[5] * 16, max_new_tokens=1))
    # an 8-token prompt (1 page) admits; the first decode step then
    # needs a second page for position 8 and must raise the typed pool
    # exhaustion even though 2 of 3 slots are still free
    eng.submit(Request(rid=0, prompt=[5] * 8, max_new_tokens=16))
    with pytest.raises(PagePoolExhausted):
        for _ in range(16):
            eng.step()
    assert eng.cache.allocator.num_free == NUM_SLOTS - 1
    assert issubclass(PagePoolExhausted, RuntimeError)


# ---------------------------------------------------------------------------
# typed-exception + warmup regressions (reuse the compiled engines)
# ---------------------------------------------------------------------------


def test_engine_config_errors_are_typed_and_O_safe():
    """__init__ validation must raise EngineConfigError (a ValueError),
    not assert — asserts vanish under ``python -O``."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.reduced import reduced
    from repro.launch.mesh import make_mesh
    from repro.serving import EngineConfig, EngineConfigError, ServingEngine
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    params = {}   # validation fires before params are ever touched
    enc_cfg = reduced(get_config("seamless-m4t-medium", hnn_mode="ann"))
    with pytest.raises(EngineConfigError):
        ServingEngine(enc_cfg, mesh, params, EngineConfig())  # enc-dec
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, params,
                      EngineConfig(num_slots=2, max_seq=32, spec_k=-1))
    assert issubclass(EngineConfigError, ValueError)


def test_run_stall_raises_scheduler_stall():
    from repro.serving import Request, SchedulerStall
    _, batched, _, _ = _engines()
    with pytest.raises(SchedulerStall):
        batched.run([Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8)],
                    max_steps=2)
    # drain the stalled request so the engine is clean for other tests
    while not batched.idle:
        batched.step()
    _assert_drained(batched)


def test_warmup_rid_never_collides_with_user_rids():
    """A user request whose rid equals warmup's old sentinel (-1) must
    keep its results; WARMUP_RID is an unforgeable object."""
    from repro.serving import Request, WARMUP_RID
    _, batched, _, _ = _engines()
    batched.warmup([1, 2, 3, 4])
    assert batched.tokens_generated == 0          # stats reset
    res = batched.run([Request(rid=-1, prompt=[5, 6, 7], max_new_tokens=3)])
    assert set(res) == {-1} and len(res[-1]) <= 3
    assert WARMUP_RID not in res
    assert WARMUP_RID != -1 and WARMUP_RID != "warmup"
    _assert_drained(batched)
