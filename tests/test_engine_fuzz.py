"""Randomized-schedule fuzz of the serving engine (single device).

Property: for ANY schedule — mixed prompt lengths, per-request
``max_new_tokens``, eos hits, queue pressure beyond the slot pool — every
request's greedy output equals a solo run of the same request (batch
composition can never leak between slots), for both the vanilla engine
and the speculative one, and the page allocator ends every run with all
pages free (no slot/page leaks through admit/retire/accept/rollback).

Runs under hypothesis when installed (``pip install -e .[dev]``); without
it the ``@given`` property pytest-skips (tests/_hyp.py) and the fixed
deterministic schedules below still exercise the same invariants.

Engines are built once per module (compile cost) and reused across
schedules: a drained engine is a clean engine — that reuse is itself part
of the property.
"""
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st

PREFILL_LEN = 16
MAX_SEQ = 32
NUM_SLOTS = 3
VOCAB = 256
EOS = 7

_ENGINES = None


def _engines():
    """(cfg, batched vanilla, batched spec_k=2, solo) — built lazily once."""
    global _ENGINES
    if _ENGINES is None:
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.configs.reduced import reduced
        from repro.launch import specs as SP, train as TR
        from repro.launch.mesh import make_mesh
        from repro.serving import EngineConfig, ServingEngine

        mesh = make_mesh((1, 1), ("data", "model"))
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
            dtype=jnp.float32, codec="none")
        cell = ShapeCell("serve_decode", MAX_SEQ, NUM_SLOTS, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        kw = dict(num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
                  prefill_len=PREFILL_LEN, page_size=8, eos_id=EOS)
        batched = ServingEngine(cfg, mesh, params, EngineConfig(**kw))
        spec = ServingEngine(cfg, mesh, params,
                             EngineConfig(**kw, spec_k=2))
        solo = ServingEngine(cfg, mesh, params, EngineConfig(**kw))
        _ENGINES = (cfg, batched, spec, solo)
    return _ENGINES


def _assert_drained(engine):
    alloc = engine.cache.allocator
    assert engine.idle
    assert alloc.num_free == NUM_SLOTS, "slot leak"
    assert alloc.pages_in_use == 0, "page leak"
    assert (alloc._len == 0).all(), "stale occupancy"
    assert (alloc.block_table == -1).all(), "stale block-table mapping"


def _check_schedule(schedule):
    """schedule: list of (prompt_len, max_new_tokens) pairs."""
    from repro.serving import Request
    _, batched, spec, solo = _engines()
    rng = np.random.RandomState(1234)
    reqs = [Request(rid=i, prompt=list(rng.randint(0, VOCAB, plen)),
                    max_new_tokens=mnt)
            for i, (plen, mnt) in enumerate(schedule)]

    def clone(r):
        return Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)

    res = batched.run([clone(r) for r in reqs])
    res_spec = spec.run([clone(r) for r in reqs])
    assert set(res) == {r.rid for r in reqs}
    for r in reqs:
        ref = solo.run([clone(r)])[r.rid]
        assert res[r.rid] == ref, (r.rid, ref, res[r.rid])
        assert res_spec[r.rid] == ref, ("spec", r.rid, ref, res_spec[r.rid])
        # output contract: exactly max_new_tokens unless eos cut it short
        if len(ref) < r.max_new_tokens:
            assert ref[-1] == EOS
        _assert_drained(solo)
    _assert_drained(batched)
    _assert_drained(spec)


# ---------------------------------------------------------------------------
# fixed deterministic schedules (always run, no hypothesis needed)
# ---------------------------------------------------------------------------


def test_fixed_schedule_queue_pressure():
    """7 mixed-length requests through 3 slots: admits interleave with
    retirements and the queue drains in arrival order."""
    _check_schedule([(16, 6), (3, 1), (16, 8), (1, 4), (9, 8), (16, 2),
                     (5, 5)])


def test_fixed_schedule_single_and_short():
    _check_schedule([(1, 1)])
    _check_schedule([(16, 12), (16, 12), (16, 12)])


# ---------------------------------------------------------------------------
# hypothesis property (skips cleanly when hypothesis is not installed)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(1, PREFILL_LEN),
                          st.integers(1, 8)),
                min_size=1, max_size=2 * NUM_SLOTS + 1))
def test_fuzz_schedules_match_solo_and_leak_free(schedule):
    _check_schedule(schedule)


# ---------------------------------------------------------------------------
# block-table paging: O(page_size) admits + typed pool exhaustion
# ---------------------------------------------------------------------------


def test_admit_maps_prompt_pages_not_max_seq():
    """Acceptance: admitting a ``prompt_len == page_size`` request maps
    O(page_size) KV bytes — ONE page — while the old slot-major layout
    charged the slot its full ``max_seq`` reservation up front."""
    from repro.serving import Request
    _, batched, _, _ = _engines()
    cache = batched.cache
    page_size = cache.allocator.page_size
    batched._admit(Request(rid=0, prompt=list(range(1, page_size + 1)),
                           max_new_tokens=4))
    assert not batched._retired
    assert cache.allocator.pages_in_use == 1
    assert cache.kv_bytes_mapped() == cache.kv_page_bytes() > 0
    # dense reservation would have charged pages_per_slot pages NOW
    dense_slot = cache.allocator.pages_per_slot * cache.kv_page_bytes()
    assert cache.kv_bytes_mapped() * cache.allocator.pages_per_slot \
        == dense_slot
    assert cache.kv_bytes_mapped() < dense_slot
    # drain so the module-shared engine stays clean for other tests
    while not batched.idle:
        batched.step()
    _assert_drained(batched)


def test_hybrid_family_mixes_paged_kv_and_slot_major_state():
    """A hybrid (attention + mamba) cache tree carries pool-shaped KV
    leaves and slot-major state leaves through the same insert/decode/
    evict cycle: only attention KV is paged, recurrent state stays
    slot-major, and the engine still drains page-clean."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.serving import EngineConfig, Request, ServingEngine

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("jamba-1.5-large-398b",
                             hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    params = TR.init_sharded_params(
        cfg, SP.make_plan(cfg, ShapeCell("serve_decode", 32, 2, "decode"),
                          mesh), mesh, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        num_slots=2, max_seq=32, prefill_len=16, page_size=8))
    # pool leaves exist (attn layers) AND slot-major state leaves exist
    assert eng.cache.kv_page_bytes() > 0
    assert eng.cache.state_bytes_per_slot() > 0
    rng = np.random.RandomState(0)
    res = eng.run([Request(rid=i, prompt=list(rng.randint(0, 256, 16)),
                           max_new_tokens=6) for i in range(3)])
    assert len(res) == 3 and all(len(v) == 6 for v in res.values())
    alloc = eng.cache.allocator
    assert alloc.pages_in_use == 0 and (alloc.block_table == -1).all()


def test_page_pool_exhaustion_is_typed_and_pool_bound():
    """``PagePoolExhausted`` fires when (and only when) the PAGE POOL is
    the binding limit: slots are still free, but a live slot's growth
    has no page left to map.  Built on a deliberately undersized pool
    (3 pages < pages_per_slot * num_slots = 12)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.serving import (EngineConfig, PagePoolExhausted, Request,
                               ServingEngine)

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    params = TR.init_sharded_params(
        cfg, SP.make_plan(cfg, ShapeCell("serve_decode", MAX_SEQ,
                                         NUM_SLOTS, "decode"), mesh),
        mesh, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        num_slots=NUM_SLOTS, max_seq=MAX_SEQ, prefill_len=PREFILL_LEN,
        page_size=8, num_pages=1))
    # a prompt that could NEVER fit the 1-page pool is refused at submit
    with pytest.raises(ValueError):
        eng.submit(Request(rid=9, prompt=[5] * 16, max_new_tokens=1))
    # an 8-token prompt (1 page) admits; the first decode step then
    # needs a second page for position 8 and must raise the typed pool
    # exhaustion even though 2 of 3 slots are still free
    eng.submit(Request(rid=0, prompt=[5] * 8, max_new_tokens=16))
    with pytest.raises(PagePoolExhausted):
        for _ in range(16):
            eng.step()
    assert eng.cache.allocator.num_free == NUM_SLOTS - 1
    assert issubclass(PagePoolExhausted, RuntimeError)


# ---------------------------------------------------------------------------
# typed-exception + warmup regressions (reuse the compiled engines)
# ---------------------------------------------------------------------------


def test_engine_config_errors_are_typed_and_O_safe():
    """__init__ validation must raise EngineConfigError (a ValueError),
    not assert — asserts vanish under ``python -O``."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.reduced import reduced
    from repro.launch.mesh import make_mesh
    from repro.serving import EngineConfig, EngineConfigError, ServingEngine
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode="ann")).replace(
        dtype=jnp.float32, codec="none")
    params = {}   # validation fires before params are ever touched
    enc_cfg = reduced(get_config("seamless-m4t-medium", hnn_mode="ann"))
    with pytest.raises(EngineConfigError):
        ServingEngine(enc_cfg, mesh, params, EngineConfig())  # enc-dec
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, params,
                      EngineConfig(num_slots=2, max_seq=32, spec_k=-1))
    assert issubclass(EngineConfigError, ValueError)


def test_run_stall_raises_scheduler_stall():
    from repro.serving import Request, SchedulerStall
    _, batched, _, _ = _engines()
    with pytest.raises(SchedulerStall):
        batched.run([Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8)],
                    max_steps=2)
    # drain the stalled request so the engine is clean for other tests
    while not batched.idle:
        batched.step()
    _assert_drained(batched)


def test_warmup_rid_never_collides_with_user_rids():
    """A user request whose rid equals warmup's old sentinel (-1) must
    keep its results; WARMUP_RID is an unforgeable object."""
    from repro.serving import Request, WARMUP_RID
    _, batched, _, _ = _engines()
    batched.warmup([1, 2, 3, 4])
    assert batched.tokens_generated == 0          # stats reset
    res = batched.run([Request(rid=-1, prompt=[5, 6, 7], max_new_tokens=3)])
    assert set(res) == {-1} and len(res[-1]) <= 3
    assert WARMUP_RID not in res
    assert WARMUP_RID != -1 and WARMUP_RID != "warmup"
    _assert_drained(batched)
