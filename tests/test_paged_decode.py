"""Fused paged-decode attention: kernel-vs-oracle sweeps, compacted
per-shard page-list invariants, and engine fused-vs-reference identity.

Three layers, matching the data path:

1. ``kernels.paged_decode`` (interpret mode) against the dense
   single-softmax oracle ``kernels.ref.paged_decode_ref`` — GQA, K1 > 1
   (spec verify), sliding window, softcap, evicted slots (all ``-1``
   lists), partially filled last pages, pool much larger than the live
   set, and the int8 wire epilogue bit-matching
   ``core.boundary.quantize_partial``.

2. ``SlotAllocator`` compacted-list bookkeeping under random
   alloc/extend/rollback/free interleavings: disjointness, per-shard
   residency, position ordering, agreement with the block table, and
   the enforced (never best-effort) per-shard width invariant.

3. The serving engine end-to-end: greedy token streams of the fused
   kernel path vs the reference gather path must be identical across
   spec_k x async_depth x codec (the acceptance bar for making
   ``attn_kernel="fused"`` the default).
"""
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st

# ---------------------------------------------------------------------------
# 1. kernel vs oracle
# ---------------------------------------------------------------------------


def _rand_case(seed, B, K1, Hq, Hkv, dh, P_loc, psz, ppc, n_live=None,
               partial_last=False):
    """Random pool + well-formed compacted lists (distinct local rows,
    ascending positions) + per-slot qpos at the write frontier."""
    import jax
    import jax.numpy as jnp
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, K1, Hq, dh), jnp.float32)
    k_pool = jax.random.normal(kk, (P_loc, psz, Hkv, dh), jnp.float32)
    v_pool = jax.random.normal(kv, (P_loc, psz, Hkv, dh), jnp.float32)
    clp = np.full((B, ppc), -1, np.int32)
    clo = np.full((B, ppc), -1, np.int32)
    qpos = np.zeros((B, K1), np.int32)
    for b in range(B):
        n = rng.randint(1, ppc + 1) if n_live is None else n_live
        if n:
            clp[b, :n] = rng.choice(P_loc, n, replace=False)
            clo[b, :n] = np.sort(rng.choice(ppc * 4, n, replace=False)) * psz
            last = int(clo[b, n - 1])
            off = rng.randint(0, psz) if partial_last else psz - 1
            qpos[b] = last + max(off, K1 - 1) - np.arange(K1)[::-1]
    return (q, k_pool, v_pool, jnp.asarray(clp), jnp.asarray(clo),
            jnp.asarray(qpos))


def _assert_matches_oracle(case, window=0, cap=0.0):
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    q, kp, vp, clp, clo, qpos = case
    # interpret=True forces the Pallas kernel body (the default off-TPU
    # dispatch runs the oracle itself — see ops.paged_flash_decode)
    o, lse = ops.paged_flash_decode(q, kp, vp, clp, clo, qpos,
                                    window=window, cap=cap,
                                    interpret=True)
    oe, le = ref.paged_decode_ref(q, kp, vp, clp, clo, qpos,
                                  window=window, cap=cap)
    np.testing.assert_allclose(np.array(o), np.array(oe), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.array(lse), np.array(le), atol=2e-4,
                               rtol=2e-5)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("K1", [1, 3])
def test_kernel_matches_oracle(Hq, Hkv, K1):
    _assert_matches_oracle(_rand_case(0, B=5, K1=K1, Hq=Hq, Hkv=Hkv,
                                      dh=16, P_loc=12, psz=8, ppc=4))


@pytest.mark.parametrize("window,cap", [(24, 0.0), (0, 12.0), (16, 8.0)])
def test_kernel_window_softcap(window, cap):
    _assert_matches_oracle(_rand_case(1, B=4, K1=2, Hq=4, Hkv=4, dh=16,
                                      P_loc=10, psz=8, ppc=4),
                           window=window, cap=cap)


def test_evicted_slot_all_invalid():
    """An all ``-1`` list (evicted slot riding in the batch, or a shard
    holding none of a slot's pages) must stay finite with lse = -1e30:
    the row's o is a degenerate uniform mean (all scores masked to the
    same -1e30), but its weight in the cross-shard LSE combine is
    exp(-1e30 - m) = 0 exactly, so it can never contaminate a real
    partial — and it must agree with the oracle bit-for-bit in kind."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    q, kp, vp, clp, clo, qpos = _rand_case(2, B=3, K1=2, Hq=4, Hkv=4,
                                           dh=16, P_loc=8, psz=8, ppc=3)
    clp = clp.at[1].set(-1)
    clo = clo.at[1].set(-1)
    o, lse = ops.paged_flash_decode(q, kp, vp, clp, clo, qpos,
                                    interpret=True)
    oe, le = ref.paged_decode_ref(q, kp, vp, clp, clo, qpos)
    assert np.isfinite(np.array(o)).all()
    np.testing.assert_allclose(np.array(lse[1]), -1e30)
    np.testing.assert_allclose(np.array(le[1]), -1e30)
    np.testing.assert_allclose(np.array(o[1]), np.array(oe[1]), atol=2e-5)
    # combine weight of the dead partial is identically zero
    assert (np.exp(np.array(lse[1], np.float64) - 0.0) == 0.0).all()


def test_partial_last_page():
    """qpos strictly inside the last mapped page: positions past the
    write frontier must not score."""
    _assert_matches_oracle(_rand_case(3, B=6, K1=1, Hq=4, Hkv=4, dh=16,
                                      P_loc=9, psz=8, ppc=3,
                                      partial_last=True))


def test_pool_much_larger_than_live():
    """num_pages >> live pages: compaction means cost scales with the
    list width, and untouched pool rows never leak into the output."""
    _assert_matches_oracle(_rand_case(4, B=3, K1=2, Hq=4, Hkv=4, dh=16,
                                      P_loc=128, psz=8, ppc=2, n_live=1))


def test_wire_epilogue_matches_quantize_partial():
    """The kernel's fused int8 epilogue implements the SAME per-token
    absmax contract as the host-side ``boundary.quantize_partial`` (the
    reference path's encoder), so ``coded_combine_partials`` decodes
    either identically: scales agree to fp epsilon (the two are
    separately compiled programs, so bit-identity is not guaranteed)
    and the decoded wires agree to within one quantization step."""
    from repro.core import boundary
    from repro.kernels import ops
    q, kp, vp, clp, clo, qpos = _rand_case(5, B=4, K1=2, Hq=4, Hkv=4,
                                           dh=16, P_loc=10, psz=8, ppc=3)
    o, lse = ops.paged_flash_decode(q, kp, vp, clp, clo, qpos,
                                    interpret=True)
    we, se = boundary.quantize_partial(o)
    # both the Pallas epilogue and the off-TPU XLA dispatch must honor
    # the contract
    for interp in (True, None):
        wire, scale, lse_w = ops.paged_flash_decode(
            q, kp, vp, clp, clo, qpos, encode_wire=True,
            interpret=interp)
        assert wire.dtype == np.int8 and we.dtype == np.int8
        assert scale.shape == se.shape == (4, 2, 4, 1)
        np.testing.assert_allclose(np.array(scale), np.array(se),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.array(lse_w), np.array(lse),
                                   rtol=1e-6, atol=1e-6)
        dec_k = np.array(wire, np.float32) * np.array(scale)
        dec_h = np.array(we, np.float32) * np.array(se)
        step = np.array(se)
        assert (np.abs(dec_k - dec_h) <= step + 1e-7).all()
        # int8 range actually used, never overflowed
        assert np.abs(np.array(wire)).max() <= 127


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       gqa=st.sampled_from([(4, 4), (4, 2), (8, 2)]),
       K1=st.integers(1, 3),
       psz=st.sampled_from([4, 8]),
       ppc=st.integers(1, 5),
       window=st.sampled_from([0, 16]),
       partial=st.booleans())
def test_fuzz_kernel_vs_oracle(seed, gqa, K1, psz, ppc, window, partial):
    Hq, Hkv = gqa
    _assert_matches_oracle(
        _rand_case(seed % 100000, B=3, K1=K1, Hq=Hq, Hkv=Hkv, dh=8,
                   P_loc=4 * ppc + 3, psz=psz, ppc=ppc,
                   partial_last=partial),
        window=window)


# ---------------------------------------------------------------------------
# 2. allocator compacted-list invariants
# ---------------------------------------------------------------------------


def _check_lists(a):
    """Every structural invariant the fused kernel relies on."""
    live_all = []
    for slot in range(a.num_slots):
        pages = a._pages[slot]
        live_all.extend(pages)
        g = a.group_of(slot)
        base = g * a.pages_per_group
        seen = []
        for s in range(a.shards_per_group):
            cnt = int(a._shard_count[slot, s])
            loc = a.page_list_loc[slot, s]
            pos = a.page_list_pos[slot, s]
            # compact prefix, -1 beyond
            assert (loc[:cnt] >= 0).all() and (loc[cnt:] == -1).all()
            assert (pos[:cnt] >= 0).all() and (pos[cnt:] == -1).all()
            # per-shard residency + local-row range
            assert (loc[:cnt] < a.pages_local).all()
            # strictly increasing positions (ordinal order within shard)
            assert (np.diff(pos[:cnt]) > 0).all()
            for j in range(cnt):
                page = base + s * a.pages_local + int(loc[j])
                assert a._shard_of(page) == s
                ordinal = pages.index(page)       # raises if not resident
                assert int(pos[j]) == ordinal * a.page_size
                seen.append(page)
        # the lists name exactly the slot's pages, each once
        assert sorted(seen) == sorted(pages)
        # block table agrees
        bt = a.block_table[slot]
        assert list(bt[:len(pages)]) == pages
        assert (bt[len(pages):] == -1).all()
    # pool-wide disjointness
    assert len(live_all) == len(set(live_all))


def _mk_alloc(**kw):
    from repro.serving.kv_cache import SlotAllocator
    base = dict(num_slots=4, max_seq=64, page_size=8, num_pages=24,
                num_groups=2, shards_per_group=2)
    base.update(kw)
    return SlotAllocator(**base)


def test_compacted_list_width():
    a = _mk_alloc()
    assert a.pages_per_slot == 8
    assert a.pages_per_shard == 4                 # ceil(8 / 2)
    assert a.page_list_loc.shape == (4, 2, 4)
    b = _mk_alloc(shards_per_group=3, num_pages=24)
    assert b.pages_per_shard == 3                 # ceil(8 / 3)


def test_compacted_lists_track_lifecycle():
    a = _mk_alloc()
    s0 = a.alloc(20)                              # 3 pages
    s1 = a.alloc(64)                              # 8 pages (full)
    _check_lists(a)
    a.extend(s0, 12)                              # -> 4 pages
    _check_lists(a)
    a.rollback(s1, 33)                            # 8 -> 5 pages
    _check_lists(a)
    a.free(s0)
    _check_lists(a)
    assert (a.page_list_loc[s0] == -1).all()
    assert int(a._shard_count.sum()) == a.pages_in_use == 5
    a.free(s1)
    _check_lists(a)
    assert a.pages_in_use == 0
    assert (a.page_list_loc == -1).all() and (a.page_list_pos == -1).all()


def test_balanced_placement_fills_shards_evenly():
    a = _mk_alloc()
    s0 = a.alloc(64)                              # 8 pages over 2 shards
    assert list(a._shard_count[s0]) == [4, 4]
    _check_lists(a)


def test_width_invariant_enforced_not_best_effort():
    """Drain one shard's free range: placement must route to the other
    shard until ITS width is exhausted, then raise typed — an
    overflowing page would be invisible to the fused kernel."""
    from repro.serving.errors import PagePoolExhausted
    a = _mk_alloc(num_slots=2, num_groups=1, num_pages=12,
                  shards_per_group=2)             # pages_local=6, width=4
    a._free_pages[0][1].clear()                   # shard 1 dry
    assert a._fresh_capacity(0) == 4 < a.free_pages_in_group(0) == 6
    s0 = a.alloc(32)                              # 4 pages, all shard 0
    assert list(a._shard_count[s0]) == [4, 0]
    _check_lists(a)
    with pytest.raises(PagePoolExhausted):
        a.ensure(s0, 33)                          # shard 0 width is full
    assert not a.can_admit(40)                    # 5 pages > capacity 2
    assert a.can_admit(16)


def test_degenerate_single_shard_matches_block_table():
    """shards_per_group=1 (single-device engine): the one compacted list
    is the block table's live prefix, locally renumbered."""
    a = _mk_alloc(num_groups=1, shards_per_group=1, num_pages=32)
    s = a.alloc(30)
    assert a.pages_per_shard == a.pages_per_slot
    np.testing.assert_array_equal(
        a.page_list_loc[s, 0, :4], a.block_table[s, :4] % a.pages_local)
    np.testing.assert_array_equal(a.page_list_pos[s, 0, :4],
                                  np.arange(4) * a.page_size)
    _check_lists(a)


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shards=st.sampled_from([1, 2, 4]),
       steps=st.integers(5, 40))
def test_fuzz_allocator_invariants(seed, shards, steps):
    """Random alloc/extend/rollback/free interleavings keep every
    compacted-list invariant, including under exhaustion."""
    from repro.serving.errors import PagePoolExhausted, SlotsExhausted
    rng = np.random.RandomState(seed % 100000)
    a = _mk_alloc(num_slots=4, max_seq=64, page_size=8, num_pages=16,
                  num_groups=1, shards_per_group=shards)
    live = {}
    for _ in range(steps):
        op = rng.randint(4)
        try:
            if op == 0:
                n = int(rng.randint(1, 65))
                live[a.alloc(n)] = n
            elif op == 1 and live:
                s = rng.choice(sorted(live))
                live[s] = min(64, live[s] + int(rng.randint(1, 17)))
                a.ensure(s, live[s])
            elif op == 2 and live:
                s = rng.choice(sorted(live))
                live[s] = int(rng.randint(1, live[s] + 1))
                a.rollback(s, live[s])
            elif op == 3 and live:
                s = rng.choice(sorted(live))
                a.free(s)
                del live[s]
        except (PagePoolExhausted, SlotsExhausted):
            pass
        _check_lists(a)
    for s in sorted(live):
        a.free(s)
    _check_lists(a)
    assert a.pages_in_use == 0


# ---------------------------------------------------------------------------
# 3. engine: fused vs reference token identity
# ---------------------------------------------------------------------------

PREFILL_LEN = 16
MAX_SEQ = 32
NUM_SLOTS = 3
VOCAB = 256
EOS = 7

_MODELS = {}
_ENGINES = {}


def _model(codec):
    if codec not in _MODELS:
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        from repro.configs.reduced import reduced
        from repro.launch import specs as SP, train as TR
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config("qwen1.5-0.5b", hnn_mode=hnn)).replace(
            dtype=jnp.float32, codec=codec)
        cell = ShapeCell("serve_decode", MAX_SEQ, NUM_SLOTS, "decode")
        plan = SP.make_plan(cfg, cell, mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        _MODELS[codec] = (cfg, mesh, params)
    return _MODELS[codec]


def _engine(codec, kernel, spec_k, async_depth):
    key = (codec, kernel, spec_k, async_depth)
    if key not in _ENGINES:
        from repro.serving import EngineConfig, ServingEngine
        cfg, mesh, params = _model(codec)
        _ENGINES[key] = ServingEngine(
            cfg, mesh, params,
            EngineConfig(num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
                         prefill_len=PREFILL_LEN, page_size=8, eos_id=EOS,
                         spec_k=spec_k, async_depth=async_depth,
                         attn_kernel=kernel))
    return _ENGINES[key]


def _run_schedule(eng, schedule, seed=77):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    reqs = [Request(rid=i, prompt=list(rng.randint(0, VOCAB, plen)),
                    max_new_tokens=mnt)
            for i, (plen, mnt) in enumerate(schedule)]
    return eng.run(reqs)


_SCHEDULE = [(16, 6), (3, 4), (9, 5), (1, 3), (12, 6)]


@pytest.mark.parametrize("codec", ["none", "spike_fused"])
@pytest.mark.parametrize("spec_k", [0, 2])
@pytest.mark.parametrize("async_depth", [0, 1])
def test_engine_fused_matches_reference(codec, spec_k, async_depth):
    """The acceptance bar: byte-identical greedy streams from the fused
    Pallas path and the reference dense-gather path, across speculative
    and pipelined variants and both codecs."""
    ref = _run_schedule(_engine(codec, "reference", spec_k, async_depth),
                        _SCHEDULE)
    fus = _run_schedule(_engine(codec, "fused", spec_k, async_depth),
                        _SCHEDULE)
    assert set(ref) == set(fus)
    for rid in ref:
        assert fus[rid] == ref[rid], (codec, spec_k, async_depth, rid)
    for eng in (_engine(codec, "reference", spec_k, async_depth),
                _engine(codec, "fused", spec_k, async_depth)):
        alloc = eng.cache.allocator
        assert alloc.pages_in_use == 0 and alloc.pages_in_limbo == 0


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(schedule=st.lists(
    st.tuples(st.integers(1, PREFILL_LEN), st.integers(1, 8)),
    min_size=1, max_size=6))
def test_fuzz_engine_fused_matches_reference(schedule):
    """Random schedules (queue pressure, mixed lengths, eos) through the
    sync vanilla pair — the cheapest combo, fuzzed hardest."""
    ref = _run_schedule(_engine("none", "reference", 0, 0), schedule)
    fus = _run_schedule(_engine("none", "fused", 0, 0), schedule)
    assert ref == fus


def test_engine_rejects_unknown_kernel():
    from repro.serving import EngineConfig
    from repro.serving.errors import EngineConfigError
    cfg, mesh, params = _model("none")
    from repro.serving import ServingEngine
    with pytest.raises(EngineConfigError):
        ServingEngine(cfg, mesh, params,
                      EngineConfig(num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
                                   prefill_len=PREFILL_LEN, page_size=8,
                                   attn_kernel="dense"))
