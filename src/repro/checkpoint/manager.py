"""Sharded checkpointing with async write, atomic commit, and elastic
re-sharding on restore.

Layout (one directory per step):
    ckpt_dir/step_000123/
        MANIFEST.json            # tree structure, shapes, dtypes, specs
        shard_<host>_<i>.npz     # this host's param/opt shards
        COMMIT                   # written last: marks the step complete

Fault-tolerance contract:
  * save() is atomic — a crash mid-write leaves no COMMIT, and restore()
    picks the newest committed step.
  * async mode runs the serialization + fsync off the training thread
    (overlaps with the next steps; wait() joins before the next save).
  * restore(..., mesh) re-shards to whatever mesh the job restarted
    with (elastic scaling: 512 -> 256 chips just works — arrays are saved
    as full logical tensors per leaf from the addressable shards).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding


def _tree_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), v) for kp, v in flat]


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True):
        """Snapshot to host memory synchronously, write to disk (async if
        blocking=False)."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree):
        path = os.path.join(self.dir, f"step_{step:09d}")
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _tree_paths(host_tree)
        manifest = {"step": step, "leaves": []}
        arrays = {}
        for i, (name, arr) in enumerate(leaves):
            key = f"a{i}"
            dt = str(arr.dtype)
            if dt == "bfloat16":   # npz can't store bf16; save raw bits
                arr = arr.view(np.uint16)
            manifest["leaves"].append(
                {"path": name, "key": key, "shape": list(arr.shape),
                 "dtype": dt})
            arrays[key] = arr
        np.savez(os.path.join(tmp, "shard_0_0.npz"), **arrays)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write(str(time.time()))
        os.replace(tmp, path) if not os.path.exists(path) else None
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def committed_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "COMMIT")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                mesh=None, specs=None):
        """Restore into ``template``'s structure.  If mesh+specs given,
        device_put with those shardings (elastic re-shard)."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no committed checkpoint"
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0_0.npz"))
        by_path = {}
        for l in manifest["leaves"]:
            arr = data[l["key"]]
            if l["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            by_path[l["path"]] = arr
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        vals = []
        for kp, tmpl in flat:
            arr = by_path[jax.tree_util.keystr(kp)]
            assert tuple(arr.shape) == tuple(tmpl.shape), (
                jax.tree_util.keystr(kp), arr.shape, tmpl.shape)
            vals.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, vals)
        if mesh is not None and specs is not None:
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
            tree = jax.device_put(tree, sh)
        return tree, step
