"""NoC simulation framework — faithful re-implementation of the paper's
contribution (3): latency/throughput/energy for ANN, SNN, and HNN
mappings on the 2-D mesh NoC accelerator (paper §3-4).

Architecture constants follow Tables 1-3:
  * 8x8 core grid per chip; HNN: 28 boundary spiking + 36 interior
    artificial cores; ANN: 64 artificial; SNN: 64 spiking.
  * 200 MHz NoC, 65 nm, 1.0 V; 256 neurons/axons per core.
  * EMIO: 8-to-1 mux, 38-cycle serialization; 76-cycle die-to-die packet
    latency with pipelined deserialization (eq 8).
  * X-Y routing with directional-X mapping (eqs 4-5).
  * latency eqs (6), (7), (9); ORION-2.0-style energy scaled to the
    65 nm / 200 MHz / 1.0 V point; SNN ACC ~ 0.06x MAC energy; die-to-die
    packet ~ 10x MAC, 224x core-to-core hop (paper §4.4).

The model mapper consumes layer shapes (neurons in/out, MACs) — either
hand-specified or derived from a ``repro.configs`` ModelConfig — and
produces per-component latency/energy, reproducing Figs 10-13.

Two serving-trace front-ends bridge the SLO harness into this model:

``NocSim.simulate_trace(steps)``
    Cycle-level: maps each step's per-collective packet streams (the
    ``wire_streams`` breakdown an ``SLOMonitor`` records when the
    engine's ``wire_stream_profile()`` is registered) onto the
    boundary serdes ports and router hops individually — each
    collective pays its own eq (8) serialization (ceil over the ``nc``
    peripheral ports: dependent collectives cannot pack partial serdes
    batches), pipelined deserialization, and hop fill, and contributes
    PE/MEM/Router/EMIO energy per §4.4.  Returns per-step and total
    cycles + an energy breakdown; ``TraceReport.to_dict()`` is the
    ``cosim`` block the ``--cosim`` benches embed in BENCH_serve.json.

``emio_cost_from_trace(steps)``
    Closed-form cross-check: prices the aggregate ``wire_bytes`` scalar
    with eq (8) directly (floor over the aggregate).  The cycle-level
    total is guaranteed to bound it from above —
    ``sum(ceil(pb_i/nc)) >= floor(sum(pb_i)/nc)`` plus the
    deserialize/hop terms — which tests/test_sim.py asserts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence


@dataclasses.dataclass(frozen=True)
class NocConfig:
    cores_per_chip: int = 64       # 8x8 grid (Tab 1); Fig 11/13 sweep 8-64
    neurons_per_core: int = 256    # grouping G
    freq_hz: float = 200e6
    bits: int = 8                  # activation precision
    T: int = 8                     # rate-code tick window (paper: T=8)
    spike_sparsity: float = 0.9    # 90% sparsity (10% activity, §4.2)
    mode: str = "hnn"              # ann | snn | hnn
    # energy constants (normalized to one 8-bit MAC at 65nm ~ 1.0 pJ
    # baseline, paper §4.4 scalings)
    e_mac: float = 1.0
    e_acc: float = 0.20            # SNN accumulate (+scheduler/membrane
                                   # upkeep; Dampfhoffer et al. [6] range)
    e_sram_rw: float = 0.15        # per-operand SRAM access (scaled /bit)
    e_hop: float = 0.045           # router hop, core-to-core per packet
    e_d2d_factor: float = 224.0    # die-to-die = 224x core-to-core hop
    cycles_ser: int = 38           # EMIO serialization (eq 8)
    cycles_des: int = 38

    @property
    def grid(self) -> int:
        return max(2, int(math.sqrt(self.cores_per_chip)))

    @property
    def boundary_cores(self) -> int:
        # peripheral ring (28 of 64 at 8x8, paper Tab 1); small chips are
        # all-boundary
        g = self.grid
        ring = 4 * g - 4
        return min(self.cores_per_chip, max(ring, 1))

    @property
    def e_d2d(self) -> float:
        return self.e_hop * self.e_d2d_factor


@dataclasses.dataclass(frozen=True)
class Layer:
    """One mapped layer: dense (fc) or conv already flattened to MACs."""

    name: str
    n_in: int
    n_out: int
    macs: int                      # MAC count for a dense ANN layer
    kind: str = "fc"               # fc | conv | dwconv | pool


def fc(name, n_in, n_out):
    return Layer(name, n_in, n_out, n_in * n_out, "fc")


def conv(name, cin, cout, k, h, w):
    return Layer(name, cin * h * w, cout * h * w,
                 cout * h * w * cin * k * k, "conv")


@dataclasses.dataclass
class LayerReport:
    name: str
    cores: int
    cycles_compute: float
    cycles_emio: float
    local_packets: float
    routed_packets: float
    boundary_packets: float
    e_pe: float
    e_mem: float
    e_router: float
    e_emio: float

    @property
    def cycles(self):
        return self.cycles_compute + self.cycles_emio

    @property
    def energy(self):
        return self.e_pe + self.e_mem + self.e_router + self.e_emio


@dataclasses.dataclass
class SimReport:
    layers: List[LayerReport]
    cfg: NocConfig

    @property
    def total_cycles(self):
        return sum(l.cycles for l in self.layers)

    @property
    def latency_s(self):
        return self.total_cycles / self.cfg.freq_hz

    @property
    def total_energy(self):
        return sum(l.energy for l in self.layers)

    @property
    def chips(self):
        total_cores = sum(l.cores for l in self.layers)
        return max(1, math.ceil(total_cores / self.cfg.cores_per_chip))

    def breakdown(self):
        return {
            "PE": sum(l.e_pe for l in self.layers),
            "MEM": sum(l.e_mem for l in self.layers),
            "Router": sum(l.e_router for l in self.layers),
            "EMIO": sum(l.e_emio for l in self.layers),
        }


class NocSim:
    """Layer-accurate ANN/SNN/HNN simulator (paper §4.2-4.4)."""

    def __init__(self, cfg: NocConfig):
        self.cfg = cfg

    # -- eq (4): average hops between layer midpoints (directional-X map)
    def average_hops(self, cores_prev: int, cores_cur: int) -> float:
        m_prev = cores_prev / 2.0 / self.cfg.grid
        m_cur = cores_cur / 2.0 / self.cfg.grid
        return abs(m_cur - m_prev) + 1.0

    def _spiking_layer(self, idx: int, n_layers: int) -> bool:
        m = self.cfg.mode
        if m == "ann":
            return False
        if m == "snn":
            return True
        # hnn: spiking only where the partition crosses a chip boundary;
        # layers are packed chips-worth of cores at a time, so the layers
        # whose core allocation crosses a chip edge spike (approximated
        # as: every layer that starts a new chip — see _map()).
        return True  # decided per-layer in simulate() for hnn

    # ------------------------------------------------------------------
    def simulate(self, layers: Sequence[Layer], timesteps=None) -> SimReport:
        cfg = self.cfg
        T = timesteps or cfg.T
        act = 1.0 - cfg.spike_sparsity          # firing activity
        reports = []
        cores_prev = cfg.cores_per_chip
        core_budget = 0                          # cores used on this chip

        for i, L in enumerate(layers):
            cores = max(1, math.ceil(L.n_out / cfg.neurons_per_core))
            crosses_chip = (core_budget + cores) > cfg.cores_per_chip
            if crosses_chip:
                core_budget = (core_budget + cores) % cfg.cores_per_chip
            else:
                core_budget += cores

            # --- compute domain ------------------------------------
            # SNN: every core spikes (ACC PEs, eq 7).  ANN: dense MACs
            # (eq 6).  HNN: layers mapped across a die boundary run on
            # the peripheral spiking cores (SNN compute + spike wire,
            # §5.3 "computational cost reduction inherent in SNN
            # layers"); interior layers stay dense ANN.
            G = cfg.neurons_per_core
            spiking = (cfg.mode == "snn") or (cfg.mode == "hnn"
                                              and crosses_chip)
            if spiking:
                ops = L.macs * T * act
                cyc_compute = ops / (G * math.ceil(L.n_out / G))
                e_pe = ops * cfg.e_acc
                mem_scale = 0.5                  # 8b weights + potentials
                dense_flits = T * act            # spike packets on-chip too
                wire_flits = T * act
            else:
                ops = L.macs
                # Tab 2 PE is an 8bx8b MAC: wider data is multi-cycle
                # (latency x bits/8); switching energy per completed MAC
                # is dominated by the array + SRAM and stays ~flat
                cyc_compute = ops * (cfg.bits / 8.0) \
                    / (G * math.ceil(L.n_out / G))
                e_pe = ops * cfg.e_mac
                mem_scale = 1.0
                dense_flits = cfg.bits / 8.0     # 8-b payload flits (Tab 3)
                wire_flits = cfg.bits / 8.0

            # on-chip packets (eqs 4-5): "local packets" are the copies
            # received through each destination core's local port — every
            # core computing this layer needs every input activation, so
            # the fan-out multiplies the traffic (this is what makes
            # Router/EMIO grow superlinearly with model size, §4.4)
            # fc: every core needs every input; conv: operand streams
            # bounded by macs/G per core (weight-stationary reuse)
            fanout = min(L.n_in * cores, L.macs / G)
            local_packets = fanout * dense_flits
            hops = self.average_hops(cores_prev, cores)
            routed = hops * local_packets
            e_router = routed * cfg.e_hop
            e_mem = ops * cfg.e_sram_rw * mem_scale * (cfg.bits / 8.0)

            cyc_emio = 0.0
            e_emio = 0.0
            boundary_packets = 0.0
            if crosses_chip:
                # one serdes copy per far-side chip the layer spans
                far_chips = max(1, cores // cfg.cores_per_chip)
                pb = min(L.n_in * far_chips, L.macs / G) * wire_flits
                nc = min(cores, cfg.boundary_cores)
                # eq (8): parallel serialization over peripheral ports,
                # pipelined deserialization
                cyc_emio = (math.floor(pb / nc) * cfg.cycles_ser
                            + pb * 1.0)
                e_emio = pb * cfg.e_d2d
                boundary_packets = pb
                if cfg.mode == "hnn":
                    # CLP conversion cost: IF accumulate per tick on the
                    # boundary neurons (activation<->spike, Fig 4)
                    e_pe += L.n_out * T * act * cfg.e_acc

            reports.append(LayerReport(
                L.name, cores, cyc_compute, cyc_emio, local_packets,
                routed, boundary_packets, e_pe, e_mem, e_router, e_emio))
            cores_prev = cores
        return SimReport(reports, cfg)

    # ------------------------------------------------------------------
    def simulate_trace(self, steps: Sequence[dict]) -> TraceReport:
        """Cycle-level pricing of a serving step trace's boundary
        traffic, one collective stream at a time.

        ``steps`` is an ``SLOMonitor.step_trace()`` record list (or the
        ``slo.load_trace`` of its JSONL): each record's
        ``wire_streams`` maps collective stream kind (psum /
        head_all_gather / partial_combine / kv_migrate / ...) to the
        die-to-die bytes that collective moved during the tick; records
        without a stream split fall back to pricing the aggregate
        ``wire_bytes`` as one ``"total"`` stream.

        Each stream of ``pb`` bytes (one 8-bit boundary packet per
        byte) pays, over the ``nc`` peripheral serdes ports:

        * ``ceil(pb / nc) * cycles_ser`` serialization batches — ceil,
          not eq (8)'s floor-on-the-aggregate, because collectives
          execute in dependency order and cannot pack a partial final
          serdes batch with the next collective's packets;
        * ``pb`` pipelined transfer cycles plus one ``cycles_des``
          deserialization drain and a ``grid/4 + 1`` hop fill from the
          interior compute cores to the peripheral ring (eqs 4-5's
          average-hop shape for a boundary-bound stream);
        * energy per §4.4: ``e_d2d`` per packet at the boundary,
          ``e_hop`` per packet-hop getting there, one spike/activation
          accumulate (``e_acc``) per packet of boundary encode/decode
          work, and an SRAM read + write (``2 * e_sram_rw``).

        Summed over streams this strictly upper-bounds the closed-form
        ``emio_cost_from_trace`` figure for the same trace.
        """
        cfg = self.cfg
        nc = max(1, cfg.boundary_cores)
        hops = cfg.grid / 4.0 + 1.0
        out: List[TraceStepReport] = []
        for s in steps:
            streams = dict(s.get("wire_streams") or {})
            if not streams:
                total = float(s.get("wire_bytes", 0.0))
                if total > 0:
                    streams = {"total": total}
            cyc = e_pe = e_mem = e_router = e_emio = 0.0
            for pb in streams.values():
                pb = float(pb)
                if pb <= 0:
                    continue
                cyc += (math.ceil(pb / nc) * cfg.cycles_ser + pb
                        + cfg.cycles_des + hops)
                e_emio += pb * cfg.e_d2d
                e_router += pb * hops * cfg.e_hop
                e_pe += pb * cfg.e_acc
                e_mem += 2.0 * pb * cfg.e_sram_rw
            out.append(TraceStepReport(
                kind=str(s.get("kind", "")),
                tokens=int(s.get("tokens", 0)), cycles=cyc,
                e_pe=e_pe, e_mem=e_mem, e_router=e_router,
                e_emio=e_emio,
                bytes_by_stream={k: float(v) for k, v in streams.items()
                                 if float(v) > 0}))
        return TraceReport(out, cfg)


# ---------------------------------------------------------------------------
# paper benchmark models (§4.1) mapped to layer lists
# ---------------------------------------------------------------------------


def rwkv_layers(d_model=512, n_layers=6, vocab=256) -> List[Layer]:
    """Paper's 6-layer, 512-dim RWKV (Enwik8)."""
    out: List[Layer] = [fc("embed", vocab, d_model)]
    for i in range(n_layers):
        out += [
            fc(f"L{i}.tm_kvr", d_model, 3 * d_model),
            fc(f"L{i}.tm_out", d_model, d_model),
            fc(f"L{i}.cm_k", d_model, 4 * d_model),
            fc(f"L{i}.cm_v", 4 * d_model, d_model),
        ]
    out.append(fc("head", d_model, vocab))
    return out


def msresnet18_layers(img=32, classes=100) -> List[Layer]:
    """MS-ResNet18 on CIFAR-100 (paper Fig 5)."""
    out = [conv("stem", 3, 64, 3, img, img)]
    ch = [(64, img), (128, img // 2), (256, img // 4), (512, img // 8)]
    prev_c = 64
    for b, (c, hw) in enumerate(ch):
        for u in range(2):
            out.append(conv(f"b{b}u{u}c1", prev_c, c, 3, hw, hw))
            out.append(conv(f"b{b}u{u}c2", c, c, 3, hw, hw))
            prev_c = c
    out.append(fc("head", 512, classes))
    return out


def efficientnet_b4_layers(img=380, classes=1000) -> List[Layer]:
    """EfficientNet-B4 (approximate MBConv workload, paper §4.2)."""
    out = [conv("stem", 3, 48, 3, img // 2, img // 2)]
    # (expansion, channels, layers, stride, kernel)
    blocks = [(1, 24, 2, 1, 3), (6, 32, 4, 2, 3), (6, 56, 4, 2, 5),
              (6, 112, 6, 2, 3), (6, 160, 6, 1, 5), (6, 272, 8, 2, 5),
              (6, 448, 1, 1, 3)]
    c_in, hw = 48, img // 2
    for e, c, n, s, k in blocks:
        for i in range(n):
            stride = s if i == 0 else 1
            hw = max(4, hw // stride)
            mid = c_in * e
            out.append(conv(f"mb{c}_{i}e", c_in, mid, 1, hw, hw))
            out.append(Layer(f"mb{c}_{i}d", mid * hw * hw, mid * hw * hw,
                             mid * hw * hw * k * k, "dwconv"))
            out.append(conv(f"mb{c}_{i}p", mid, c, 1, hw, hw))
            c_in = c
    out.append(fc("head", c_in, classes))
    return out


PAPER_MODELS = {
    "rwkv": rwkv_layers,
    "msresnet18": msresnet18_layers,
    "efficientnet-b4": efficientnet_b4_layers,
}


# ---------------------------------------------------------------------------
# serving-trace -> NoC co-simulation bridge
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceStepReport:
    """Cycle-level cost of one serving tick's boundary traffic."""

    kind: str                       # step kind ("decode"/"verify"/"drain")
    tokens: int
    cycles: float                   # serdes + deserialize + hop fill
    e_pe: float                     # boundary encode/decode accumulates
    e_mem: float                    # SRAM read (encode) + write (decode)
    e_router: float                 # hops from compute cores to the ring
    e_emio: float                   # die-to-die packets (224x hop, §4.4)
    bytes_by_stream: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def energy(self):
        return self.e_pe + self.e_mem + self.e_router + self.e_emio


@dataclasses.dataclass
class TraceReport:
    """``NocSim.simulate_trace`` result: per-step reports + totals."""

    steps: List[TraceStepReport]
    cfg: NocConfig

    @property
    def tokens(self):
        return sum(s.tokens for s in self.steps)

    @property
    def total_cycles(self):
        return sum(s.cycles for s in self.steps)

    @property
    def total_energy(self):
        return sum(s.energy for s in self.steps)

    def breakdown(self):
        return {
            "PE": sum(s.e_pe for s in self.steps),
            "MEM": sum(s.e_mem for s in self.steps),
            "Router": sum(s.e_router for s in self.steps),
            "EMIO": sum(s.e_emio for s in self.steps),
        }

    def bytes_by_stream(self):
        out: Dict[str, float] = {}
        for s in self.steps:
            for k, v in s.bytes_by_stream.items():
                out[k] = out.get(k, 0.0) + v
        return dict(sorted(out.items()))

    def to_dict(self) -> dict:
        """The per-codec ``cosim`` block of a BENCH_serve/v1 payload
        (sans the closed-form cross-check figure, which the bench adds
        from ``emio_cost_from_trace``).  Energy is in normalized-pJ
        (e_mac = 1.0 pJ at 65 nm), so joules = energy * 1e-12."""
        toks = max(self.tokens, 1)
        return {
            "steps": len(self.steps),
            "tokens": self.tokens,
            "noc_cycles": self.total_cycles,
            "noc_cycles_per_token": self.total_cycles / toks,
            "noc_us_per_token": (self.total_cycles / toks
                                 / self.cfg.freq_hz * 1e6),
            "energy_breakdown": self.breakdown(),
            "energy_per_token": self.total_energy / toks,
            "joules_per_token": self.total_energy / toks * 1e-12,
            "wire_kb_by_stream": {k: v / 1e3
                                  for k, v in self.bytes_by_stream().items()},
        }


def emio_cost_from_trace(steps: Sequence[dict],
                         cfg: NocConfig | None = None) -> dict:
    """Price a serving engine's per-step wire-bytes trace on the EMIO.

    ``steps`` is the record list an ``SLOMonitor`` step trace exports
    (``slo.load_trace`` / ``SLOMonitor.step_trace()``): each dict needs
    ``wire_bytes`` — the total die-to-die bytes the tick's device step
    moved, from the compiled step's parsed collectives — and ``tokens``
    (committed that tick).  Every byte on the coded wire is one 8-bit
    boundary packet, so a step's serialization cost follows eq (8) —
    ``floor(pb / nc) * cycles_ser + pb`` over the ``nc`` peripheral
    serdes ports — and its energy is ``pb * e_d2d`` (224x a router hop,
    §4.4).  The returned per-token numbers are the co-simulation
    headline: what the measured serving workload, not a synthetic
    layer sweep, pays at the die boundary per generated token.
    """
    cfg = cfg or NocConfig()
    nc = max(1, cfg.boundary_cores)
    cycles = energy = mig_bytes = 0.0
    tokens = 0
    for s in steps:
        pb = float(s.get("wire_bytes", 0.0))
        if pb > 0:
            cycles += math.floor(pb / nc) * cfg.cycles_ser + pb
            energy += pb * cfg.e_d2d
        tokens += int(s.get("tokens", 0))
        # disagg KV migrations are already folded into wire_bytes (and
        # thus priced above); surface their share for the report
        mig_bytes += float(s.get("mig_bytes", 0.0))
    return {
        "steps": len(steps),
        "tokens": tokens,
        "emio_cycles": cycles,
        "emio_s": cycles / cfg.freq_hz,
        "e_emio": energy,
        "emio_cycles_per_token": cycles / max(tokens, 1),
        "e_emio_per_token": energy / max(tokens, 1),
        "mig_bytes": mig_bytes,
    }
