"""Spike-based encoding core (paper §3.5, eqs 1-3, 10).

Implements the learnable spike sparsification used at die-to-die
(→ TPU: inter-chip collective) boundaries:

* LIF neuron dynamics (eq 1) with surrogate gradients,
* deterministic rate coding: activation -> T-tick spike train (eq 2,
  corrected; see DESIGN.md §2) and its inverse decode (eq 3),
* a closed-form "fused" count encoder that is bit-identical to summing
  the deterministic spike train but avoids materializing T ticks,
* the hinge sparsity regularizer (eq 10),
* 4-bit two-per-byte packing for the wire format.

Everything is pure jnp and jax.grad-compatible; Pallas kernels in
``repro.kernels`` provide the TPU hot-path versions and are validated
against these references.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Surrogate gradients
# ---------------------------------------------------------------------------


@jax.custom_vjp
def spike_step(v: jax.Array, beta: float = 10.0) -> jax.Array:
    """Heaviside H(v) with fast-sigmoid surrogate gradient.

    Forward: 1.0 where v >= 0.  Backward: d/dv sigma_fast(beta*v)
    = beta / (1 + beta*|v|)^2 (Eshraghian et al., "Training SNNs using
    lessons from deep learning").
    """
    return (v >= 0.0).astype(v.dtype)


def _spike_step_fwd(v, beta):
    return spike_step(v, beta), (v, beta)


def _spike_step_bwd(res, g):
    v, beta = res
    surr = beta / jnp.square(1.0 + beta * jnp.abs(v))
    return (g * surr.astype(g.dtype), None)


spike_step.defvjp(_spike_step_fwd, _spike_step_bwd)


@jax.custom_vjp
def round_ste(x: jax.Array) -> jax.Array:
    """Round with straight-through gradient."""
    return jnp.round(x)


def _round_ste_fwd(x):
    return jnp.round(x), None


def _round_ste_bwd(_, g):
    return (g,)


round_ste.defvjp(_round_ste_fwd, _round_ste_bwd)


# ---------------------------------------------------------------------------
# LIF neuron (eq 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """Static LIF hyperparameters (per-boundary)."""

    beta: float = 0.9          # membrane decay e^{-dt/tau}
    surrogate_slope: float = 10.0
    reset: str = "subtract"    # "subtract" | "zero"


def lif_step(u: jax.Array, i_t: jax.Array, theta: jax.Array,
             p: LIFParams) -> tuple[jax.Array, jax.Array]:
    """One LIF tick: U_{t+1} = beta*U_t + (1-beta)*I_t, spike on U>=theta.

    Returns (new_membrane, spike).  ``theta`` may be per-channel
    (learnable) and is broadcast against ``u``.
    """
    u = p.beta * u + (1.0 - p.beta) * i_t
    s = spike_step(u - theta, p.surrogate_slope)
    if p.reset == "subtract":
        u = u - s * theta
    else:
        u = u * (1.0 - s)
    return u, s


def lif_rate_encode(x: jax.Array, theta: jax.Array, T: int,
                    p: LIFParams = LIFParams()) -> tuple[jax.Array, jax.Array]:
    """Paper-faithful T-tick LIF encoder (lax.scan over ticks).

    The activation ``x`` is held as a constant input current for T ticks
    (static-data rate coding, paper §3.3: "static dataset inputs must be
    encoded with multiple timesteps").  Returns:

      counts: float array, values in {0..T} (sum of the spike train;
              float so surrogate grads flow),
      spikes: [T, *x.shape] binary train (for inspection / SNN mode).
    """
    def tick(u, _):
        u, s = lif_step(u, x, theta, p)
        return u, s

    u0 = jnp.zeros_like(x)
    _, spikes = jax.lax.scan(tick, u0, None, length=T)
    counts = jnp.sum(spikes, axis=0)
    return counts, spikes


# ---------------------------------------------------------------------------
# Deterministic rate coding (eqs 2, 3 — corrected; DESIGN.md §2)
# ---------------------------------------------------------------------------


def rate_encode(x: jax.Array, scale: jax.Array, theta: jax.Array,
                T: int) -> jax.Array:
    """Closed-form deterministic rate code: x -> spike count in {0..T}.

    Equivalent to emitting a regular spike train with
    ``count = round(clip(x,0,scale)/scale * T)`` and a learnable firing
    threshold ``theta``: channels whose normalized drive is below
    theta/scale emit nothing (the learned-sparsity gate).  Gradients flow
    via straight-through rounding + surrogate threshold.

    Returns float counts (for differentiability); quantize with
    ``counts.astype(jnp.uint8)`` at the wire.
    """
    xn = jnp.clip(x, 0.0, None) / scale
    gate = spike_step(x - theta, 10.0)
    c = round_ste(jnp.clip(xn, 0.0, 1.0) * T) * gate
    return c


def rate_decode(counts: jax.Array, scale: jax.Array, T: int) -> jax.Array:
    """Paper eq (3): a_i = (2^b - 1)/T * sum_t s_i(t), generalized to a
    learned/calibrated float ``scale`` in place of (2^b - 1)."""
    return counts.astype(scale.dtype) * (scale / T)


# ---------------------------------------------------------------------------
# Signed variant: boundary activations (post-norm residual streams) are
# signed; the paper's rate code is unsigned (8-bit activations).  We encode
# sign in a symmetric code: counts in [-T, T], carried as uint8 with bias T
# (still <= 4 bits + 1 sign bit => fits a 5-bit field; pack8 uses 1 byte,
# pack4 restricts T<=7).
# ---------------------------------------------------------------------------


def rate_encode_signed(x: jax.Array, scale: jax.Array, theta: jax.Array,
                       T: int) -> jax.Array:
    """Signed symmetric rate code: counts in {-T..T} (float)."""
    mag = jnp.abs(x)
    gate = spike_step(mag - theta, 10.0)
    c = round_ste(jnp.clip(mag / scale, 0.0, 1.0) * T) * gate
    return jnp.sign(x) * c


def rate_decode_signed(counts: jax.Array, scale: jax.Array, T: int) -> jax.Array:
    return counts.astype(scale.dtype) * (scale / T)


def if_rate_encode(drive: jax.Array, T: int) -> jax.Array:
    """Paper-faithful CLP rate coder (Fig 4a): integrate-and-fire
    accumulator.  The converter "directly accumulates the activation
    value" each tick and fires when the membrane crosses threshold
    (unit threshold after normalization), generating a spike sequence
    proportional to the activation.  drive in [0,1]; returns counts in
    {0..T}.  With u0 = 0.5 the T-tick count equals round(drive*T), i.e.
    bit-identical to the closed-form encoder.
    """
    def tick(u, _):
        u = u + drive
        s = spike_step(u - 1.0, 10.0)
        return u - s, s

    u0 = jnp.full_like(drive, 0.5)
    _, spikes = jax.lax.scan(tick, u0, None, length=T)
    return jnp.sum(spikes, axis=0)


def lif_rate_encode_signed(x, theta, T, p: LIFParams = LIFParams()):
    """Paper-faithful signed encoder: two IF populations (on/off cells).
    Positive drive feeds one population, negative the other; the wire
    value is the count difference.  ``theta`` is the learnable firing
    gate (channels below it stay silent — the learned sparsity).
    ``x`` is pre-normalized drive (x/scale)."""
    del p  # boundary coder is the IF accumulator; LIF stays for SNN layers
    mag = jnp.abs(x)
    gate = spike_step(mag - theta, 10.0)
    c_pos = if_rate_encode(jnp.clip(x, 0.0, 1.0), T)
    c_neg = if_rate_encode(jnp.clip(-x, 0.0, 1.0), T)
    return (c_pos - c_neg) * gate


# ---------------------------------------------------------------------------
# Sparsity regularizer (eq 10)
# ---------------------------------------------------------------------------


def sparsity_loss(counts: jax.Array, T: int, target_rate: float,
                  lam: float) -> jax.Array:
    """L_sparse = lam * hinge(mean firing rate - target).

    The paper activates the penalty "only when the desired sparsity is
    exceeded in the training run"; firing rate = mean(|counts|)/T.
    """
    rate = jnp.mean(jnp.abs(counts)) / T
    return lam * jnp.maximum(rate - target_rate, 0.0)


def firing_rate(counts: jax.Array, T: int) -> jax.Array:
    """Mean firing rate in [0,1] (fraction of possible spikes emitted)."""
    return jnp.mean(jnp.abs(counts)) / T


def occupancy(counts: jax.Array) -> jax.Array:
    """Fraction of channels that fired at all (1 - sparsity)."""
    return jnp.mean((jnp.abs(counts) > 0).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Wire packing: counts {-T..T} -> uint8 (bias-T) and 4-bit two-per-byte
# ---------------------------------------------------------------------------


def counts_to_wire_u8(counts: jax.Array, T: int) -> jax.Array:
    """Signed counts -> biased uint8 (value + T). Needs 2T+1 <= 256."""
    return (counts + T).astype(jnp.uint8)


def wire_u8_to_counts(wire: jax.Array, T: int, dtype=jnp.float32) -> jax.Array:
    return wire.astype(dtype) - T


def pack4(wire: jax.Array) -> jax.Array:
    """Pack uint8 values < 16 two-per-byte along the last axis.

    Last axis must be even. out[..., k] = v[2k] | v[2k+1] << 4.
    """
    lo = wire[..., 0::2]
    hi = wire[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack4(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Boundary parameter container + init
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpikeConfig:
    """Static config for one spike boundary."""

    T: int = 15                # ticks; 15 -> signed counts fit 5 bits; use 7 for pack4
    target_rate: float = 0.10  # paper: 90% sparsity
    lam: float = 1e-3
    lif: LIFParams = LIFParams()
    faithful: bool = False     # True: lax.scan LIF train; False: closed form


def init_spike_params(dim: int, dtype=jnp.float32) -> dict:
    """Learnable per-channel threshold + scale for one boundary."""
    return {
        "theta": jnp.full((dim,), 0.01, dtype),
        "log_scale": jnp.zeros((dim,), dtype),  # scale = exp(log_scale)
    }


def encode(x: jax.Array, params: dict, cfg: SpikeConfig) -> jax.Array:
    """Activation -> signed float counts in {-T..T}. Differentiable."""
    scale = jnp.exp(params["log_scale"]).astype(x.dtype)
    theta = params["theta"].astype(x.dtype)
    if cfg.faithful:
        # IF accumulator over T ticks; scale normalizes drive, and the
        # learnable gate is applied in normalized units.
        return lif_rate_encode_signed(x / scale, theta / scale, cfg.T,
                                      cfg.lif)
    return rate_encode_signed(x, scale, theta, cfg.T)


def decode(counts: jax.Array, params: dict, cfg: SpikeConfig,
           dtype=jnp.bfloat16) -> jax.Array:
    scale = jnp.exp(params["log_scale"]).astype(dtype)
    return rate_decode_signed(counts, scale, cfg.T).astype(dtype)


def roundtrip_vjp(x, theta, log_scale, g, cfg: SpikeConfig,
                  surr_beta: float = 10.0):
    """Hand-derived VJP of y = decode(encode(x)) for the signed rate code.

    y = sign(x) * gate(|x|-theta) * (s/T) * round_ste(clip(|x|/s,0,1)*T)

    STE through round, surrogate fast-sigmoid through the gate:
      dy/dx  = gate * 1[0<|x|<s]  +  (c_mag*s/T) * surr(|x|-theta)
      dy/dth = -sign(x) * c_mag * (s/T) * surr(|x|-theta)
      dy/dls = sign(x)*gate * ( -|x| * 1[in] + c_mag*s/T )

    ~5 elementwise ops, no linearization residuals — this is what makes
    the boundary backward HBM-neutral (EXPERIMENTS.md §Perf, iteration 1).
    """
    f32 = jnp.float32
    xf = x.astype(f32)
    gf = g.astype(f32)
    s = jnp.exp(log_scale.astype(f32))
    th = theta.astype(f32)
    T = float(cfg.T)
    mag = jnp.abs(xf)
    sgn = jnp.sign(xf)
    in_rng = ((mag > 0) & (mag < s)).astype(f32)
    gate = (mag >= th).astype(f32)
    c_mag = jnp.round(jnp.clip(mag / s, 0.0, 1.0) * T)
    ymag = c_mag * (s / T)
    v = mag - th
    surr = surr_beta / jnp.square(1.0 + surr_beta * jnp.abs(v))

    dx = gf * (gate * in_rng + ymag * surr)
    dth = -gf * sgn * ymag * surr
    dls = gf * sgn * gate * (-mag * in_rng + ymag)
    # reduce param grads over token dims
    red = tuple(range(x.ndim - 1))
    return (dx.astype(x.dtype),
            jnp.sum(dth, axis=red).astype(theta.dtype),
            jnp.sum(dls, axis=red).astype(log_scale.dtype))
