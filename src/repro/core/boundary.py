"""Spike-coded boundary collectives — the paper's die-to-die interface on TPU.

Every tensor that crosses a chip boundary on TPU moves through a
collective.  ``BoundaryCodec`` wraps the four collectives the framework
uses (all_gather / psum_scatter / ppermute / all_to_all) so that the bytes
on the ICI wire are spike counts (int8, or packed uint4) instead of
bf16/f32 activations.  Modes:

  none        : plain bf16 collective (the ANN baseline).
  int8        : per-channel absmax int8 quantization (ablation baseline).
  spike       : paper-faithful — T-tick LIF (lax.scan) per boundary, int8
                signed counts on the wire. 2x fewer bytes than bf16.
  spike_fused : closed-form count encoder (bit-identical wire for the
                deterministic rate code), no T-tick scan. 2x bytes.
  spike_pack4 : fused encoder with T<=7, two counts per byte. 4x bytes.
  sparse_topk : event-driven packets — fixed-capacity (index,count) pairs
                for the top-c fraction of active channels (beyond-paper;
                DESIGN.md §2). ~(3..5)/ (2*c) x reduction.

Gradients: the wire is integer, so each boundary is a ``jax.custom_vjp``
whose forward runs the integer collective and whose backward runs the
transpose collective on the (optionally compressed) cotangent, chained
through the local encode/decode VJP (surrogate LIF gradients + straight-
through rounding from ``repro.core.spike``).

All functions must be called inside ``shard_map`` with the named axes
bound.  The channel axis is the last axis; ``axis`` selects the token
axis being gathered/scattered.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import spike
from .spike import SpikeConfig

Axis = Any  # str | tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class BoundaryCodec:
    """Static description of one class of boundary."""

    mode: str = "none"
    cfg: SpikeConfig = SpikeConfig()
    capacity: float = 0.125        # sparse_topk capacity fraction
    bwd_mode: str = "none"         # compress backward wire too ("int8"|"none")

    def wire_bits(self) -> float:
        """Bits per boundary element on the wire (for roofline bookkeeping)."""
        if self.mode == "none":
            return 16.0
        if self.mode in ("int8", "spike", "spike_fused"):
            return 8.0
        if self.mode == "spike_pack4":
            return 4.0
        if self.mode == "sparse_topk":
            return self.capacity * (8 + 32)
        raise ValueError(self.mode)


ANN = BoundaryCodec(mode="none")
HNN_FAITHFUL = BoundaryCodec(mode="spike", cfg=SpikeConfig(T=15, faithful=True))
HNN_FUSED = BoundaryCodec(mode="spike_fused", cfg=SpikeConfig(T=15))
HNN_PACK4 = BoundaryCodec(mode="spike_pack4", cfg=SpikeConfig(T=7))


def _axis_size(axis_name: Axis) -> int:
    if isinstance(axis_name, (tuple, list)):
        n = 1
        for a in axis_name:
            n *= lax.axis_size(a)
        return n
    return lax.axis_size(axis_name)


# ---------------------------------------------------------------------------
# local encode/decode to the integer wire format
# ---------------------------------------------------------------------------


def _encode_local(x, params, codec: BoundaryCodec):
    """x float [..., C] -> (wire int tensor, decode closure, counts float)."""
    cfg = codec.cfg
    if codec.mode == "int8":
        amax = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)), keepdims=True)
        s = jnp.maximum(amax, 1e-6) / 127.0
        wire = jnp.round(x / s).astype(jnp.int8)
        return wire, s, None
    counts = spike.encode(x, params, cfg)           # float in {-T..T}
    if codec.mode == "spike_pack4":
        wire = (counts + cfg.T).astype(jnp.uint8)   # {0..14} fits 4 bits
        shp = wire.shape
        wire = spike.pack4(wire.reshape(-1, shp[-1])).reshape(
            *shp[:-1], shp[-1] // 2)
        return wire, None, counts
    wire = counts.astype(jnp.int8)
    return wire, None, counts


def _decode_local(wire, params, codec: BoundaryCodec, scale_i8, dtype):
    # decode directly in the compute dtype: counts are small integers,
    # exactly representable in bf16, and the f32 intermediate would be the
    # largest transient buffer at the boundary
    cfg = codec.cfg
    if codec.mode == "int8":
        return (wire.astype(jnp.float32) * scale_i8).astype(dtype)
    if codec.mode == "spike_pack4":
        shp = wire.shape
        u = spike.unpack4(wire.reshape(-1, shp[-1])).reshape(
            *shp[:-1], shp[-1] * 2)
        counts = u.astype(dtype) - jnp.asarray(cfg.T, dtype)
    else:
        counts = wire.astype(dtype)
    return spike.decode(counts, params, cfg, dtype)


def _local_roundtrip(x, params, codec: BoundaryCodec):
    """Differentiable local view of encode->wire->decode (for the VJP)."""
    if codec.mode == "int8":
        amax = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)), keepdims=True)
        s = jnp.maximum(amax, 1e-6) / 127.0
        return spike.round_ste(x / s) * s
    counts = spike.encode(x, params, codec.cfg)
    return spike.decode(counts, params, codec.cfg, x.dtype)


# ---------------------------------------------------------------------------
# sparsity statistics (feeds the eq-10 regularizer)
# ---------------------------------------------------------------------------


def boundary_penalty(x, params, codec: BoundaryCodec):
    """Differentiable sparsity penalty + firing-rate stat for one boundary."""
    if codec.mode in ("none", "int8"):
        return jnp.zeros((), x.dtype), jnp.zeros((), x.dtype)
    counts = spike.encode(x, params, codec.cfg)
    pen = spike.sparsity_loss(counts, codec.cfg.T, codec.cfg.target_rate,
                              codec.cfg.lam)
    occ = spike.occupancy(counts)
    return pen.astype(x.dtype), occ.astype(x.dtype)



def _roundtrip_bwd(x, theta, log_scale, g, codec: BoundaryCodec):
    """Analytic VJP of the local encode->decode roundtrip (no saved
    linearization residuals; see spike.roundtrip_vjp)."""
    if codec.mode == "int8":
        # straight-through within the absmax clip; no learnable params
        return (g.astype(x.dtype), jnp.zeros_like(theta),
                jnp.zeros_like(log_scale))
    return spike.roundtrip_vjp(x, theta, log_scale, g, codec.cfg)


# ---------------------------------------------------------------------------
# coded all_gather (tiled, along token axis)
# ---------------------------------------------------------------------------


def coded_all_gather(x, params, codec: BoundaryCodec, axis_name: Axis,
                     axis: int = 0):
    """Gather token-sharded activations across ``axis_name``; spike wire."""
    if codec.mode == "none":
        return lax.all_gather(x, axis_name, axis=axis, tiled=True)

    if codec.mode == "sparse_topk":
        return _topk_all_gather(x, params, codec, axis_name, axis)

    @jax.custom_vjp
    def _ag(x, theta, log_scale):
        p = {"theta": theta, "log_scale": log_scale}
        wire, s8, _ = _encode_local(x, p, codec)
        wire_g = lax.all_gather(wire, axis_name, axis=axis, tiled=True)
        if s8 is not None:
            # per-source-chip scales: decode segment-wise
            n = _axis_size(axis_name)
            s8_g = lax.all_gather(s8, axis_name, axis=0, tiled=False)  # [n,1..,C]
            seg = jnp.moveaxis(
                wire_g.reshape(wire_g.shape[:axis]
                               + (n, wire_g.shape[axis] // n)
                               + wire_g.shape[axis + 1:]), axis, 0)
            dec = seg.astype(jnp.float32) * s8_g.reshape(
                (n,) + (1,) * (seg.ndim - 2) + (s8.shape[-1],))
            dec = jnp.moveaxis(dec, 0, axis)
            return dec.reshape(wire_g.shape).astype(x.dtype)
        return _decode_local(wire_g, p, codec, None, x.dtype)

    def _fwd(x, theta, log_scale):
        # save primals only; the local-roundtrip VJP is recomputed in _bwd
        # (linearization residuals at [B,S,D] width dominate backward HBM)
        return _ag(x, theta, log_scale), (x, theta, log_scale)

    def _bwd(res, g):
        x, theta, log_scale = res
        if codec.bwd_mode == "int8":
            dummy = {"theta": theta, "log_scale": log_scale}
            g_loc = coded_psum_scatter(g, dummy,
                                       BoundaryCodec(mode="int8"),
                                       axis_name, axis=axis)
        else:
            g_loc = lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                     tiled=True)
        return _roundtrip_bwd(x, theta, log_scale, g_loc, codec)

    _ag.defvjp(_fwd, _bwd)
    return _ag(x, params["theta"], params["log_scale"])


# ---------------------------------------------------------------------------
# coded psum_scatter: sum of per-chip spike counts = CLP accumulate (§3.5)
# ---------------------------------------------------------------------------


def coded_psum_scatter(x, params, codec: BoundaryCodec, axis_name: Axis,
                       axis: int = 0):
    """Reduce-scatter partial sums across ``axis_name``.

    Coded modes move int8 counts with an all_to_all and accumulate the
    decoded counts locally (the paper's spike-accumulation, eq 3) —
    identical wire bytes to a reduce-scatter, no int8-overflow hazard.
    """
    if codec.mode == "none":
        return lax.psum_scatter(x, axis_name, scatter_dimension=axis,
                                tiled=True)

    n = _axis_size(axis_name)

    @jax.custom_vjp
    def _ps(x, theta, log_scale):
        p = {"theta": theta, "log_scale": log_scale}
        wire, s8, _ = _encode_local(x, p, codec)
        # split the token axis into n chunks, exchange, sum decoded chunks
        w = _split_axis(wire, n, axis)           # [n, ..., tok/n, ..., C]
        w = _a2a(w, axis_name)                   # recv one chunk per peer
        if s8 is not None:
            s8 = lax.all_gather(s8, axis_name, axis=0)   # [n, 1.., C]
            dec = _decode_local(w, p, codec, s8, x.dtype)
        else:
            dec = _decode_local(w, p, codec, None, x.dtype)
        return jnp.sum(dec, axis=0)

    def _fwd(x, theta, log_scale):
        return _ps(x, theta, log_scale), (x, theta, log_scale)

    def _bwd(res, g):
        x, theta, log_scale = res
        if codec.bwd_mode == "int8":
            dummy = {"theta": theta, "log_scale": log_scale}
            gg = coded_all_gather(g, dummy, BoundaryCodec(mode="int8"),
                                  axis_name, axis=axis)
        else:
            gg = lax.all_gather(g, axis_name, axis=axis, tiled=True)
        return _roundtrip_bwd(x, theta, log_scale, gg, codec)

    _ps.defvjp(_fwd, _bwd)
    return _ps(x, params["theta"], params["log_scale"])


def _split_axis(x, n, axis):
    """[..., tok, ...] -> [n, ..., tok/n, ...] splitting ``axis``."""
    shp = list(x.shape)
    assert shp[axis] % n == 0, (shp, n, axis)
    new = shp[:axis] + [n, shp[axis] // n] + shp[axis + 1:]
    x = x.reshape(new)
    return jnp.moveaxis(x, axis, 0)


def _a2a(x, axis_name):
    """all_to_all over leading split dim (handles tuple axis names)."""
    if isinstance(axis_name, (tuple, list)) and len(axis_name) > 1:
        # decompose: successive all_to_alls over each axis
        sizes = [lax.axis_size(a) for a in axis_name]
        n = x.shape[0]
        out = x
        # reshape leading dim [n] -> sizes, a2a each axis in turn
        out = out.reshape(tuple(sizes) + x.shape[1:])
        for i, a in enumerate(axis_name):
            out = lax.all_to_all(out, a, split_axis=i, concat_axis=i,
                                 tiled=False)
        return out.reshape((n,) + x.shape[1:])
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                          tiled=False)


# ---------------------------------------------------------------------------
# decode-path boundaries (token-replicated activations)
# ---------------------------------------------------------------------------
#
# At serving time activations are [B, 1, D] and token-REPLICATED over tp
# (every rank holds every slot's token), so the decode path has two
# boundary shapes the training collectives don't cover:
#
#   coded_psum      : all-reduce of per-rank partial sums whose wire is
#                     the coded format (spike accumulation, eq 3).
#   wire_roundtrip  : a die-to-die hop with no collective at all — the
#                     tensor is already replicated, but it still crosses
#                     the spike interface, so it is encoded/decoded
#                     locally.  This keeps decode numerics identical to
#                     the coded gather that train/prefill apply to the
#                     same boundary.
#
# Both are careful to stay BATCH-INDEPENDENT: no reduction mixes slots,
# and int8 scales are per-token.  This is the invariant that makes
# batched continuous decode produce token-for-token the same output as
# single-request decode (tests/dist_scenarios.py::serving_parity).


def wire_roundtrip(x, params, codec: BoundaryCodec):
    """Local encode->wire->decode for a replicated decode activation."""
    if codec.mode == "none":
        return x
    if codec.mode == "int8":
        # per-token scale (NOT per-channel-over-batch): decode slots must
        # not see each other's magnitudes
        s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                        1e-6) / 127.0
        return (spike.round_ste(x / s) * s).astype(x.dtype)
    if codec.mode == "sparse_topk":
        C = x.shape[-1]
        k = min(max(8, int(C * codec.capacity)), C)
        c = spike.encode(x, params, codec.cfg)
        mag = lax.stop_gradient(jnp.abs(c))
        thresh = jnp.sort(mag, axis=-1)[..., C - k][..., None]
        mask = (mag >= thresh).astype(c.dtype)
        return spike.decode(c * mask, params, codec.cfg, x.dtype)
    return _local_roundtrip(x, params, codec)


def coded_psum(x, params, codec: BoundaryCodec, axis_name: Axis):
    """All-reduce partial sums across ``axis_name``; coded wire.

    Each rank encodes its partial to the wire format, the int counts are
    exchanged (all_gather of the wire tensor), and every rank decodes and
    sums the peer contributions locally — the paper's spike-accumulation
    semantics, matching ``coded_psum_scatter`` per element so decode and
    train/prefill see the same boundary numerics.  ``sparse_topk`` falls
    back to dense counts on this path (decode tensors are [B,1,D]-tiny).
    """
    if codec.mode == "none":
        return lax.psum(x, axis_name)

    @jax.custom_vjp
    def _pr(x, theta, log_scale):
        p = {"theta": theta, "log_scale": log_scale}
        if codec.mode == "int8":
            s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                            1e-6) / 127.0
            wire = jnp.round(x / s).astype(jnp.int8)
            wire_g = lax.all_gather(wire, axis_name, axis=0, tiled=False)
            s_g = lax.all_gather(s, axis_name, axis=0, tiled=False)
            dec = wire_g.astype(jnp.float32) * s_g.astype(jnp.float32)
            return jnp.sum(dec, axis=0).astype(x.dtype)
        if codec.mode == "sparse_topk":
            counts = spike.encode(x, p, codec.cfg)
            wire = counts.astype(jnp.int8)
            wire_g = lax.all_gather(wire, axis_name, axis=0, tiled=False)
            dec = spike.decode(wire_g.astype(x.dtype), p, codec.cfg,
                               x.dtype)
            return jnp.sum(dec, axis=0)
        wire, _, _ = _encode_local(x, p, codec)
        wire_g = lax.all_gather(wire, axis_name, axis=0, tiled=False)
        dec = _decode_local(wire_g, p, codec, None, x.dtype)
        return jnp.sum(dec, axis=0)

    def _fwd(x, theta, log_scale):
        return _pr(x, theta, log_scale), (x, theta, log_scale)

    def _bwd(res, g):
        # psum's cotangent is already replicated across the axis; each
        # rank backprops it through its local encode/decode roundtrip
        x, theta, log_scale = res
        return _roundtrip_bwd(x, theta, log_scale, g, codec)

    _pr.defvjp(_fwd, _bwd)
    return _pr(x, params["theta"], params["log_scale"])


# ---------------------------------------------------------------------------
# decode-step head-space boundaries (q/kv gathers + attention combine)
# ---------------------------------------------------------------------------
#
# The decode/verify attention step crosses the die boundary three more
# times than the D-space activations above: the q/kv HEAD gathers before
# the sharded flash partial, and the LSE-weighted combine of the
# partials after it.  These tensors live in head space ([B, K1, H, dh])
# where no learned spike params exist (theta/log_scale are per-channel
# over D), so every coded mode uses the params-free per-token int8
# absmax wire here — mode "none" stays plain fp.  The combine keeps the
# LSE scalars ([B, K1, Hq] f32) uncoded: they are O(heads) scalars, the
# one piece of decode-step traffic left at full precision.  Forward-only
# (serving); batch independence holds because every scale reduces over
# the channel axis only, never across slots.


def coded_head_all_gather(x, codec: BoundaryCodec, axis_name: Axis,
                          axis: int):
    """Gather head-sharded q/k/v across ``axis_name``; int8 wire when
    coded.  Scales ride the same gather (one per token x head), so each
    segment is decoded with its source shard's scale.  The named scope
    labels the collectives in HLO metadata so
    ``launch.roofline.parse_collectives`` can attribute their bytes to
    the ``head_all_gather`` packet stream."""
    with jax.named_scope("coded_head_all_gather"):
        if codec.mode == "none":
            return lax.all_gather(x, axis_name, axis=axis, tiled=True)
        s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                        1e-6) / 127.0
        wire = jnp.round(x / s).astype(jnp.int8)
        wire_g = lax.all_gather(wire, axis_name, axis=axis, tiled=True)
        s_g = lax.all_gather(s, axis_name, axis=axis, tiled=True)
        return (wire_g.astype(jnp.float32)
                * s_g.astype(jnp.float32)).astype(x.dtype)


def quantize_partial(o):
    """Per-token int8 absmax quantization of a locally-normalized
    attention partial ``[..., dh]`` -> ``(wire int8, scale f32)``.

    Bit-identical to the fused kernel's epilogue
    (``kernels.paged_decode``), so the reference gather path and the
    fused path put the same bytes on the wire.
    """
    o = o.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(o), axis=-1, keepdims=True),
                    1e-6) / 127.0
    return jnp.round(o / s).astype(jnp.int8), s


def coded_combine_partials(wire, scale, lse, axis_names: Axis, out_dtype):
    """LSE-weighted combine of int8-coded decode partials.

    The coded twin of ``models.common.combine_decode_partials``: each
    shard contributes its epilogue-quantized partial (``wire``/``scale``
    from the kernel or ``quantize_partial``) plus fp LSE; every rank
    gathers the wire bytes, decodes locally, and performs the weighted
    sum — spike-accumulation semantics, no fp partial on the wire.  The
    named scope tags all three gathers as the ``partial_combine`` packet
    stream for ``launch.roofline.parse_collectives``.
    """
    with jax.named_scope("coded_combine_partials"):
        wire_g = lax.all_gather(wire, axis_names, axis=0, tiled=False)
        s_g = lax.all_gather(scale, axis_names, axis=0, tiled=False)
        lse_g = lax.all_gather(lse, axis_names, axis=0, tiled=False)
        m = jnp.max(lse_g, axis=0)
        w = jnp.exp(lse_g - m)
        dec = wire_g.astype(jnp.float32) * s_g.astype(jnp.float32)
        o_sum = jnp.sum(dec * w[..., None], axis=0)
        l_sum = jnp.sum(w, axis=0)
        return (o_sum / jnp.maximum(l_sum[..., None], 1e-30)).astype(out_dtype)


# ---------------------------------------------------------------------------
# coded ppermute (pipeline-stage / pod-boundary sends)
# ---------------------------------------------------------------------------


def coded_ppermute(x, params, codec: BoundaryCodec, axis_name: str,
                   perm: Sequence[tuple[int, int]]):
    if codec.mode == "none":
        return lax.ppermute(x, axis_name, perm)

    inv_perm = [(d, s) for (s, d) in perm]

    @jax.custom_vjp
    def _pp(x, theta, log_scale):
        p = {"theta": theta, "log_scale": log_scale}
        wire, s8, _ = _encode_local(x, p, codec)
        wire = lax.ppermute(wire, axis_name, perm)
        if s8 is not None:
            s8 = lax.ppermute(s8, axis_name, perm)
        return _decode_local(wire, p, codec, s8, x.dtype)

    def _fwd(x, theta, log_scale):
        return _pp(x, theta, log_scale), (x, theta, log_scale)

    def _bwd(res, g):
        x, theta, log_scale = res
        gb = lax.ppermute(g, axis_name, inv_perm)
        return _roundtrip_bwd(x, theta, log_scale, gb, codec)

    _pp.defvjp(_fwd, _bwd)
    return _pp(x, params["theta"], params["log_scale"])


# ---------------------------------------------------------------------------
# coded KV migration (disaggregated prefill -> decode state handoff)
# ---------------------------------------------------------------------------
#
# Disaggregated serving migrates a finished prefill's paged KV from a
# prefill-role dp group to a decode-role group — the paper's wire
# discipline applied to STATE transfer, not just activations.  KV lives
# in head space ([.., pages, page_size, Hkv, dh]) where no learned
# spike params exist, so like the decode-step head boundaries above the
# coded wire here is params-free int8 absmax — but with POWER-OF-TWO
# scales (``kv_pow2_scale``): scale mul/div is then exact in floating
# point and the encode is idempotent (encode(decode(encode(x))) ==
# decode(encode(x)) bit-exactly), which is what lets a coded migration
# be lossless over pool values that were already coded once at insert.
# That idempotence is the disagg == colocated token-identity story for
# ``EngineConfig.kv_wire="coded"``: both topologies roundtrip the KV at
# admission, and the migration's re-encode of the roundtripped pool
# pages reproduces the wire bytes exactly.


def kv_pow2_scale(x):
    """Per-vector (last axis) absmax int8 scale, snapped to a power of 2.

    ``s = 2^k`` with ``k`` chosen from the frexp exponent of the absmax
    ``m`` so that ``m/s <= 127`` (and ``m/s > 63.5``, keeping at least
    ~7 significant bits): exact in fp arithmetic, no log2 rounding
    hazards.  A re-encode of ``round(x/s) * s`` recovers the identical
    ``s`` — see the section comment — because the decoded absmax is an
    integer multiple of a power of two.
    """
    m = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                            keepdims=True), 1e-6)
    frac, exp = jnp.frexp(m)
    k = jnp.where(frac > 127.0 / 128.0, exp - 6, exp - 7)
    return jnp.exp2(k.astype(jnp.float32))


def kv_wire_encode(x):
    """``x [..., dh] -> (wire int8, scale f32 [..., 1])`` — the coded KV
    handoff's wire format (pow2-absmax int8 per (position, head))."""
    s = kv_pow2_scale(x)
    wire = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    return wire.astype(jnp.int8), s


def kv_wire_roundtrip(x):
    """Encode+decode ``x`` through the coded KV wire (lossy, idempotent).

    Applied at pool INSERT when ``EngineConfig.kv_wire="coded"`` — in
    the colocated AND the disaggregated engine alike — so the pool holds
    wire-representable values and a later coded migration is bit-exact.
    A 7-bit-mantissa value times a power-of-two scale is exactly
    representable in bf16 and f32, so the roundtrip is idempotent in
    either pool dtype.
    """
    wire, s = kv_wire_encode(x)
    return (wire.astype(jnp.float32) * s).astype(x.dtype)


def kv_wire_bytes(shape, dtype_bytes: int, coded: bool) -> int:
    """Wire bytes of ONE migrated KV staging buffer of ``shape``
    (``[..., dh]``): int8 counts + one f32 scale per dh-vector when
    coded, plain dtype bytes otherwise.  Host-side accounting only —
    ``SLOMonitor``/``emio_cost_from_trace`` price migrations with it."""
    n = 1
    for d in shape:
        n *= int(d)
    if not coded:
        return n * dtype_bytes
    return n + (n // int(shape[-1])) * 4


def coded_kv_migrate(x, codec: BoundaryCodec, axis_name: str,
                     perm: Sequence[tuple[int, int]]):
    """Send a paged-KV staging buffer ``x [..., dh]`` across the die
    boundary named ``axis_name`` along ``perm`` — the state-transfer
    sibling of ``coded_ppermute``.

    What rides CODED vs FP on the handoff:

    * KV page payload (this function, every attention ``kv`` /
      ``cross_kv`` leaf): pow2-absmax int8 — one int8 per element plus
      one f32 scale per (page, position, kv-head) dh-vector.  This is
      the O(prompt_len x Hkv x dh) bulk of the migration and the term
      the spike/int8 wire shrinks ~4x (bf16) to ~8x (f32 scales
      amortized over dh).
    * Recurrent/SSM state leaves (mamba/xLSTM/RWKV slot rows): FP via a
      plain ``lax.ppermute`` — they are O(1) per slot, carry
      log-space / accumulator values whose quantization would break
      greedy token identity, and are not worth coding.
    * Block-table / compacted page-list metadata: never on the device
      wire at all — the host allocator mirrors the mapping
      (``SlotAllocator.migrate_slot``), so only payload crosses.

    ``codec.mode == "none"`` sends plain fp (the ``kv_wire="fp"``
    default); every coded mode shares the one params-free int8 KV wire
    (KV is head-space — there are no learned theta/log_scale channels
    to spike against, exactly as at the decode-step head boundaries).
    Like every boundary collective, the wire/scale ppermute pair is
    what ``launch.roofline.parse_collectives`` sees, so the migration
    is priced like any other coded collective — and the named scope tags
    the ppermute pair as the ``kv_migrate`` packet stream.  Forward-only
    (serving).
    """
    with jax.named_scope("coded_kv_migrate"):
        if codec.mode == "none":
            return lax.ppermute(x, axis_name, perm)
        wire, s = kv_wire_encode(x)
        wire = lax.ppermute(wire, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        return (wire.astype(jnp.float32) * s).astype(x.dtype)


# ---------------------------------------------------------------------------
# coded all_to_all (MoE dispatch/combine)
# ---------------------------------------------------------------------------


def coded_all_to_all(x, params, codec: BoundaryCodec, axis_name: str,
                     split_axis: int, concat_axis: int):
    if codec.mode == "none":
        return lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)

    @jax.custom_vjp
    def _aa(x, theta, log_scale):
        p = {"theta": theta, "log_scale": log_scale}
        wire, s8, _ = _encode_local(x, p, codec)
        wire = lax.all_to_all(wire, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)
        if s8 is not None:
            # segment-wise decode: chunks along concat_axis are per-source
            n = _axis_size(axis_name)
            s8_g = lax.all_gather(s8, axis_name, axis=0, tiled=False)
            seg = jnp.moveaxis(
                wire.reshape(wire.shape[:concat_axis]
                             + (n, wire.shape[concat_axis] // n)
                             + wire.shape[concat_axis + 1:]), concat_axis, 0)
            dec = seg.astype(jnp.float32) * s8_g.reshape(
                (n,) + (1,) * (seg.ndim - 2) + (s8.shape[-1],))
            dec = jnp.moveaxis(dec, 0, concat_axis)
            return dec.reshape(wire.shape).astype(x.dtype)
        return _decode_local(wire, p, codec, None, x.dtype)

    def _fwd(x, theta, log_scale):
        return _aa(x, theta, log_scale), (x, theta, log_scale)

    def _bwd(res, g):
        x, theta, log_scale = res
        gb = lax.all_to_all(g, axis_name, split_axis=concat_axis,
                            concat_axis=split_axis, tiled=True)
        return _roundtrip_bwd(x, theta, log_scale, gb, codec)

    _aa.defvjp(_fwd, _bwd)
    return _aa(x, params["theta"], params["log_scale"])


# ---------------------------------------------------------------------------
# sparse_topk: event-driven fixed-capacity packets (beyond-paper)
# ---------------------------------------------------------------------------


def _topk_all_gather(x, params, codec: BoundaryCodec, axis_name: Axis,
                     axis: int):
    """Send only the top-c fraction of |count| per token: (idx, count)."""
    cfg = codec.cfg
    C = x.shape[-1]
    k = max(8, int(C * codec.capacity))
    k = min(k, C)

    @jax.custom_vjp
    def _tk(x, theta, log_scale):
        p = {"theta": theta, "log_scale": log_scale}
        counts = spike.encode(x, p, cfg)
        mag = jnp.abs(counts)
        _, idx = lax.top_k(mag, k)                       # [..., k] int32
        vals = jnp.take_along_axis(counts, idx, axis=-1).astype(jnp.int8)
        idx_g = lax.all_gather(idx.astype(jnp.int32), axis_name,
                               axis=axis, tiled=True)
        val_g = lax.all_gather(vals, axis_name, axis=axis, tiled=True)
        out = jnp.zeros(val_g.shape[:-1] + (C,), jnp.float32)
        out = _scatter_last(out, idx_g, val_g.astype(jnp.float32))
        return spike.decode(out, p, cfg, x.dtype)

    def _local(a, t, l):
        p = {"theta": t, "log_scale": l}
        c = spike.encode(a, p, cfg)
        mag = jax.lax.stop_gradient(jnp.abs(c))
        thresh = jnp.sort(mag, axis=-1)[..., C - k][..., None]
        mask = (mag >= thresh).astype(c.dtype)
        return spike.decode(c * mask, p, cfg, a.dtype)

    def _fwd(x, theta, log_scale):
        return _tk(x, theta, log_scale), (x, theta, log_scale)

    def _bwd(res, g):
        x, theta, log_scale = res
        g_loc = lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                 tiled=True)
        _, vjp = jax.vjp(_local, x, theta, log_scale)
        return vjp(g_loc)

    _tk.defvjp(_fwd, _bwd)
    return _tk(x, params["theta"], params["log_scale"])


def _scatter_last(dense, idx, vals):
    """dense[..., idx[..., j]] = vals[..., j] along last axis."""
    return jax.vmap(lambda d, i, v: d.at[i].set(v))(
        dense.reshape(-1, dense.shape[-1]),
        idx.reshape(-1, idx.shape[-1]),
        vals.reshape(-1, vals.shape[-1]),
    ).reshape(dense.shape)
