"""Reproduction of spike-coded die-to-die communication, grown toward a
production-scale serving system (see ROADMAP.md)."""
from . import compat  # noqa: F401  (backfills newer jax APIs on old installs)
