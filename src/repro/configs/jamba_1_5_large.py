"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf].
Unit of 8 layers: one attention layer per 8 (1:7), MoE every other layer
(Jamba places attention at index 4 of each 8-layer block; MoE on odd
indices).  Sub-quadratic (hybrid) -> runs the long_500k cell.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=24576,
        vocab=65536,
        pattern=("mamba_mlp", "mamba_moe", "mamba_mlp", "mamba_moe",
                 "attn", "mamba_moe", "mamba_mlp", "mamba_moe"),
        n_experts=16,
        top_k=2,
        d_ff_expert=24576,
        d_state=16,
        d_conv=4,
        expand=2,
        rope_kind="none",          # jamba uses no positional encoding
        subquadratic=True,
    )
