"""gemma2-2b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf].  26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000, window 4096, attn softcap 50, final softcap 30,
sandwich (post) norms, GeGLU.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_head=256,
        d_ff=9216,
        vocab=256000,
        pattern=("local", "global"),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norm=True,
        act="gelu",
        tie_embeddings=True,
    )
