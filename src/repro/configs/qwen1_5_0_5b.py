"""qwen1.5-0.5b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=2816 vocab=151936.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_head=64,
        d_ff=2816,
        vocab=151936,
        pattern=("attn",),
        qkv_bias=True,
    )
