"""RWKV — the paper's own LM benchmark model (§4.1, Table 4).

"a six-layer, 512-size embedding RWKV model" trained on Enwik8
(char-level).  Used by the accuracy-reproduction examples; not part of
the assigned 10-arch pool.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv-paper",
        family="rnn",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_head=64,
        d_ff=2048,
        vocab=256,
        pattern=("rwkv",),
        rope_kind="none",
        norm="layernorm",
        subquadratic=True,
    )
