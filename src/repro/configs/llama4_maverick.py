"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.  Llama-4
interleaves dense and MoE layers (every other layer MoE) with one
shared expert; unit = (attn-dense, attn-moe).
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=202048,
        pattern=("attn", "attn_moe"),
        n_experts=128,
        top_k=1,
        n_shared_experts=1,
        d_ff_expert=8192,
        rope_theta=5e5,
    )
