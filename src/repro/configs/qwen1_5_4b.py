"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-4B].

40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912 vocab=151936.
20 heads pad to 32 for tp=16 (pad waste noted in EXPERIMENTS.md).
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_head=128,
        d_ff=6912,
        vocab=151936,
        pattern=("attn",),
        qkv_bias=True,
    )
