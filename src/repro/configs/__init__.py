"""Architecture registry: ``get_config(name)`` / ``reduced(cfg)``."""
from __future__ import annotations

from .base import ModelConfig, ShapeCell, SHAPES, smoke_shape  # noqa: F401

_REGISTRY = {}


def register(fn):
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str, **overrides) -> ModelConfig:
    from . import (gemma2_2b, granite_20b, jamba_1_5_large,  # noqa: F401
                   llama4_maverick, qwen1_5_0_5b, qwen1_5_4b,
                   qwen2_moe_a2_7b, qwen2_vl_2b, rwkv_paper,
                   seamless_m4t_medium, xlstm_125m)
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def list_archs():
    from . import (gemma2_2b, granite_20b, jamba_1_5_large,  # noqa: F401
                   llama4_maverick, qwen1_5_0_5b, qwen1_5_4b,
                   qwen2_moe_a2_7b, qwen2_vl_2b, rwkv_paper,
                   seamless_m4t_medium, xlstm_125m)
    return sorted(_REGISTRY.keys())


ASSIGNED = (
    "jamba-1.5-large-398b", "qwen2-vl-2b", "gemma2-2b", "qwen1.5-0.5b",
    "qwen1.5-4b", "granite-20b", "llama4-maverick-400b-a17b",
    "qwen2-moe-a2.7b", "xlstm-125m", "seamless-m4t-medium",
)
