"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 (self-contained blocks) vocab=50304.
xLSTM[7:1]-style: mostly mLSTM with periodic sLSTM; unit of 4 =
(mlstm, mlstm, mlstm, slstm).  Recurrent -> runs the long_500k cell.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_head=192,
        d_ff=0,
        vocab=50304,
        pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        rope_kind="none",
        norm="layernorm",
        subquadratic=True,
    )
