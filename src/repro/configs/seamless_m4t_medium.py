"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

12L (decoder) + 12L encoder, d_model=1024 16H (MHA) d_ff=4096
vocab=256206.  The audio frontend is a stub per the assignment:
input_specs() provides precomputed frame embeddings [B, S_enc, D].
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_head=64,
        d_ff=4096,
        vocab=256206,
        pattern=("attn",),
        is_encdec=True,
        n_enc_layers=12,
        frontend="frames",
        norm="layernorm",
        act="gelu",
    )
