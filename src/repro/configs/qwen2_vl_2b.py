"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The vision
frontend is a stub per the assignment: input_specs() provides M-RoPE
position ids (3, B, S); patch embeddings enter as ordinary tokens.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab=151936,
        pattern=("attn",),
        qkv_bias=True,
        rope_kind="mrope",
        rope_theta=1e6,
        frontend="patches",
    )
