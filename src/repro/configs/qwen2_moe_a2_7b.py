"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408 (per expert)
vocab=151936.  60 experts pad to 64 for tp=16 (router-masked dummies).
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,
        vocab=151936,
        pattern=("attn_moe",),
        qkv_bias=True,
        n_experts=60,
        top_k=4,
        n_shared_experts=4,
        d_ff_expert=1408,
    )
