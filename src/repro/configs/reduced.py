"""Reduced (smoke-test) variants: same family/pattern, tiny dims.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation); smoke tests instantiate these on CPU and run a real
forward/train step asserting shapes + no NaNs.
"""
from __future__ import annotations

from .base import ModelConfig


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink every axis while keeping the architecture family intact."""
    n_units = max(1, min(2, cfg.n_units))
    kw = dict(
        n_layers=n_units * len(cfg.pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        window=16,
    )
    if cfg.n_experts:
        kw.update(n_experts=max(4, min(8, cfg.n_experts)),
                  top_k=min(cfg.top_k, 2),
                  d_ff_expert=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family in ("hybrid", "ssm"):
        kw.update(d_state=8, d_conv=4, expand=2, dt_rank=8)
    if cfg.is_encdec:
        kw.update(n_enc_layers=2)
    if cfg.name == "xlstm-125m":
        kw.update(d_model=64, n_heads=4, n_kv_heads=4, d_ff=0)
    return cfg.replace(**kw)
