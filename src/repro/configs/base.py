"""Model configuration schema shared by every architecture.

A config fully determines parameter shapes, the layer pattern, and which
boundary collectives exist (and therefore where the paper's spike codec
applies).  ``pattern`` is the repeating unit of block kinds; the stack is
``n_layers / len(pattern)`` scanned units (MaxText-style scanned layers
keep the HLO small at 72-layer scale).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax.numpy as jnp

BLOCK_KINDS = (
    "attn",        # dense attention + dense MLP
    "attn_moe",    # attention + MoE FFN
    "local",       # sliding-window attention + dense MLP
    "global",      # full attention + dense MLP (alias of attn for patterns)
    "mamba",       # mamba mixer only
    "mamba_mlp",   # mamba mixer + dense MLP
    "mamba_moe",   # mamba mixer + MoE FFN
    "mlstm",       # xLSTM mLSTM block (self-contained)
    "slstm",       # xLSTM sLSTM block (self-contained)
    "rwkv",        # RWKV time-mix + channel-mix
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    pattern: Tuple[str, ...] = ("attn",)

    # attention
    qkv_bias: bool = False
    rope_kind: str = "rope"          # rope|mrope|none
    rope_theta: float = 1e4
    window: int = 4096               # sliding window for 'local' blocks
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    norm: str = "rmsnorm"            # rmsnorm|layernorm
    post_norm: bool = False          # gemma2 sandwich norms
    act: str = "silu"                # silu|gelu

    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                 # 0 -> ceil(d_model/16)

    # encoder-decoder
    is_encdec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub
    frontend: str = "none"           # none|patches|frames

    # hnn / boundary
    hnn_mode: str = "hnn"            # ann|hnn|snn
    codec: str = "spike_fused"       # none|int8|spike|spike_fused|spike_pack4|sparse_topk

    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # whether this arch supports 524k decode (sub-quadratic path)
    subquadratic: bool = False

    # ---------------- derived helpers ----------------

    @property
    def n_units(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))

    def padded(self, n: int, mult: int) -> int:
        return ((n + mult - 1) // mult) * mult

    def heads_padded(self, tp: int) -> int:
        return self.padded(self.n_heads, tp)

    def kv_heads_eff(self, tp: int) -> tuple[int, bool]:
        """(#kv heads stored per shard basis, replicated?) — if n_kv_heads
        is divisible by tp we shard them, else replicate across tp."""
        if self.n_kv_heads % tp == 0:
            return self.n_kv_heads, False
        return self.n_kv_heads, True

    def ff_padded(self, tp: int) -> int:
        return self.padded(self.d_ff, tp) if self.d_ff else 0

    def ffe_padded(self, tp: int) -> int:
        return self.padded(self.d_ff_expert, tp) if self.d_ff_expert else 0

    def vocab_padded(self, tp: int) -> int:
        return self.padded(self.vocab, tp)

    def inner_padded(self, tp: int) -> int:
        return self.padded(self.d_inner, tp)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str = "train") -> ShapeCell:
    """Tiny shape for CPU smoke tests."""
    if kind == "train":
        return ShapeCell("smoke_train", 32, 2, "train")
    if kind == "prefill":
        return ShapeCell("smoke_prefill", 32, 2, "prefill")
    return ShapeCell("smoke_decode", 32, 2, "decode")
