"""Pallas hot-spot kernels, each mapped to the paper stage it serves.

Every kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd
public wrapper in ``ops.py`` that runs compiled on TPU and in interpret
mode everywhere else (the CI posture on both jax pins).

=================  ======================================================
``lif_encode``     Paper Sec. "spike-based encoding": the fused T-tick
                   integrate-and-fire rate encoder that turns a boundary
                   activation tile into signed int8 spike counts — the
                   learnable sparsifier's forward pass at the die edge.
``count_matmul``   The receiving die's first matmul fused with rate
                   decode: int8 spike counts x fp weights without ever
                   materializing the decoded activations — the "compute
                   on the coded wire" half of the paper's D2D story.
``pack4`` /        4-bit wire packing for spike counts (T <= 15), the
``unpack4``        paper's bytes-on-the-wire accounting made literal:
                   two counts per byte across the die boundary.
``paged_decode``   Serving-side extension of the same boundary ethos:
                   one kernel walks a slot's compacted per-shard page
                   list (gather), runs online-softmax flash decode over
                   K1 >= 1 query positions (decode and speculative
                   verify), and emits the int8-quantized partial +LSE
                   wire the coded cross-shard combine consumes — the
                   attention analog of encode-at-the-boundary, with no
                   dense ``[B, pages * page_size, Hkv, dh]`` gather in
                   HBM.
=================  ======================================================
"""
