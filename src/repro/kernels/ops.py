"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container)
they run in interpret mode, which executes the kernel body in Python and
is used by the test suite to validate against the ``ref.py`` oracles.

Ragged shapes are padded up to block multiples here so the kernels can
assume aligned tiles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .count_matmul import count_matmul_pallas
from .lif_encode import lif_encode_pallas
from .pack4 import pack4_pallas, unpack4_pallas
from .paged_decode import paged_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult0: int, mult1: int):
    m, c = x.shape
    pm = (-m) % mult0
    pc = (-c) % mult1
    if pm or pc:
        x = jnp.pad(x, ((0, pm), (0, pc)))
    return x, (m, c)


@partial(jax.jit, static_argnames=("T", "interpret"))
def lif_encode(x: jax.Array, theta: jax.Array, scale: jax.Array, *,
               T: int = 15,
               interpret: bool | None = None) -> jax.Array:
    """Fused T-tick IF rate encoder. x [M,C] -> int8 counts [M,C]."""
    interp = (not _on_tpu()) if interpret is None else interpret
    (M, C) = x.shape
    bm = 8 if M < 256 else 256
    bc = 128 if C < 512 else 512
    xp, (m0, c0) = _pad_to(x, bm, bc)
    tp = jnp.pad(theta, (0, xp.shape[1] - C), constant_values=1e9)
    sp = jnp.pad(scale, (0, xp.shape[1] - C), constant_values=1.0)
    out = lif_encode_pallas(xp, tp, sp, T=T, block_m=bm,
                            block_c=bc, interpret=interp)
    return out[:m0, :c0]


@partial(jax.jit, static_argnames=("T", "out_dtype", "interpret"))
def count_matmul(counts: jax.Array, w: jax.Array, scale: jax.Array, *,
                 T: int = 15, out_dtype=jnp.bfloat16,
                 interpret: bool | None = None) -> jax.Array:
    """int8 counts [M,K] x w [K,N] with fused rate decode."""
    interp = (not _on_tpu()) if interpret is None else interpret
    M, K = counts.shape
    _, N = w.shape
    bm = 8 if M < 256 else 256
    bn = 128 if N < 256 else 256
    bk = 128 if K < 512 else 512
    cp, (m0, _) = _pad_to(counts, bm, bk)
    wp, _ = _pad_to(w, bk, bn)
    sp = jnp.pad(scale, (0, cp.shape[1] - K))
    out = count_matmul_pallas(cp, wp, sp, T=T, block_m=bm, block_n=bn,
                              block_k=bk, out_dtype=out_dtype,
                              interpret=interp)
    return out[:m0, :N]


@partial(jax.jit, static_argnames=("interpret",))
def pack4(wire: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    M, C = wire.shape
    bm = 8 if M < 256 else 256
    bc = 256 if C < 1024 else 1024
    xp, (m0, _) = _pad_to(wire, bm, bc)
    out = pack4_pallas(xp, block_m=bm, block_c=bc, interpret=interp)
    return out[:m0, : C // 2]


@partial(jax.jit, static_argnames=("interpret",))
def unpack4(packed: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    M, C2 = packed.shape
    bm = 8 if M < 256 else 256
    bc = 128 if C2 < 512 else 512
    xp, (m0, _) = _pad_to(packed, bm, bc)
    out = unpack4_pallas(xp, block_m=bm, block_c=bc, interpret=interp)
    return out[:m0, : C2 * 2]


@partial(jax.jit,
         static_argnames=("window", "cap", "encode_wire", "interpret"))
def paged_flash_decode(q, k_pool, v_pool, cl_page, cl_pos, qpos, *,
                       window: int = 0, cap: float = 0.0,
                       encode_wire: bool = False,
                       interpret: bool | None = None):
    """Fused page-gather -> flash decode -> LSE partial over one shard.

    q [B,K1,Hq,dh] x this shard's pool slice [P_loc,psz,Hkv,dh], walking
    the slot's compacted page list (cl_page local rows / cl_pos absolute
    start positions, [B,ppc], -1 = none).  Returns ``(o, lse)`` or, with
    ``encode_wire``, the epilogue-quantized ``(wire, scale, lse)`` for
    the coded cross-shard combine.  Grid is (B,) — no padding needed.

    Dispatch: on TPU the Pallas kernel runs compiled.  Off-TPU the
    default (``interpret=None``) runs the SAME compacted algorithm
    through XLA via the ``ref.py`` oracle — the page-list compaction
    (each shard visits ``ceil(pages/shards)`` pages, never the full
    block-table width) is a backend-independent win, while the
    in-kernel fusion (no gathered K/V intermediate in HBM, epilogue
    quantize) only pays on a real accelerator and interpret-mode
    Pallas would bury it in per-program overhead.  ``interpret=True``
    forces the interpreted kernel body — the knob the kernel-vs-oracle
    tests and the CI kernel lane use to validate the Pallas code path
    on every pinned jax.
    """
    if interpret is None and not _on_tpu():
        o, lse = ref.paged_decode_ref(q, k_pool, v_pool, cl_page, cl_pos,
                                      qpos, window=window, cap=cap)
        if not encode_wire:
            return o, lse
        # same per-token absmax int8 contract as the kernel epilogue
        # and core.boundary.quantize_partial
        s = jnp.maximum(jnp.max(jnp.abs(o), axis=-1, keepdims=True),
                        1e-6) / 127.0
        return jnp.round(o / s).astype(jnp.int8), s, lse
    interp = (not _on_tpu()) if interpret is None else interpret
    return paged_decode_pallas(q, k_pool, v_pool, cl_page, cl_pos, qpos,
                               window=window, cap=cap,
                               encode_wire=encode_wire, interpret=interp)


__all__ = ["lif_encode", "count_matmul", "pack4", "unpack4",
           "paged_flash_decode", "ref"]
