"""Pallas TPU kernel: fused paged-decode attention over compacted lists.

One kernel instance per batch slot walks the slot's compacted per-shard
page list (host-built by ``serving.kv_cache.SlotAllocator`` next to the
block table) and fuses the three stages the reference path runs
separately:

    page gather -> online-softmax flash decode (K1 >= 1 query tokens,
    covering both the decode K1=1 case and spec verify) -> locally
    normalized partial + LSE for the cross-shard combine,

optionally with the int8 wire encode of the attention output fused at
the epilogue (the ``pack4.py`` / ``lif_encode.py`` idiom): the partial
leaves the kernel already quantized for the coded die-to-die combine,
so neither a ``[B, pages_per_slot*psz, Hkv, dh]`` gathered KV block nor
an fp partial ever materializes in HBM.  Work per slot is
``pages_per_shard = ceil(pages_per_slot / pool_shards)`` pages — the
1/cp page-count reduction the dense layout had — instead of the full
block table the reference gather scores and masks.

Numerics: f32 throughout, same -1e30 masking sentinel and 1e-30
normalizer floors as ``models.common.verify_attention_partial``.  The
online per-page max/rescale reduction is mathematically identical to
the reference's single-max softmax but associates differently, so
results agree to fp epsilon, not bit-for-bit; greedy token-identity of
the served stream is what the engine fuzz enforces.  A fully masked
shard (no resident page at <= qpos) yields lse ~= -1e30 exactly like
the reference, so its weight underflows to exactly 0 in the combine.

Block layout: grid (B,); the pool shard [P_loc, psz, Hkv, dh] is
resident for all programs; q / page-list / qpos tiles are per-slot rows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _paged_decode_kernel(q_ref, k_ref, v_ref, clp_ref, clo_ref, qpos_ref,
                         *out_refs, scale: float, window: int, cap: float,
                         encode_wire: bool):
    q = q_ref[0].astype(F32)                        # [K1, Hq, dh]
    qpos = qpos_ref[0]                              # [K1]
    K1, Hq, dh = q.shape
    psz, Hkv = k_ref.shape[1], k_ref.shape[2]
    g = Hq // Hkv
    ppc = clp_ref.shape[1]

    def page_step(c, carry):
        m, l, acc = carry
        row = clp_ref[0, c]
        valid = row >= 0
        safe = jnp.where(valid, row, 0)
        sl = (pl.ds(safe, 1), slice(None), slice(None), slice(None))
        k_pg = pl.load(k_ref, sl)[0].astype(F32)    # [psz, Hkv, dh]
        v_pg = pl.load(v_ref, sl)[0].astype(F32)
        if g > 1:
            k_pg = jnp.repeat(k_pg, g, axis=1)      # [psz, Hq, dh]
            v_pg = jnp.repeat(v_pg, g, axis=1)
        s = jnp.einsum("qhd,khd->qhk", q, k_pg) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        k_pos = clo_ref[0, c] + jnp.arange(psz)
        mask = valid & (k_pos[None, None, :] <= qpos[:, None, None])
        if window:
            mask &= (qpos[:, None, None] - k_pos[None, None, :]) < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("qhk,khd->qhd", p, v_pg))
        return m_new, l_new, acc_new

    m0 = jnp.full((K1, Hq), -1e30, F32)
    l0 = jnp.zeros((K1, Hq), F32)
    a0 = jnp.zeros((K1, Hq, dh), F32)
    m, l, acc = jax.lax.fori_loop(0, ppc, page_step, (m0, l0, a0))
    o = acc / jnp.maximum(l[..., None], 1e-30)      # locally normalized
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    if encode_wire:
        wire_ref, scale_ref, lse_ref = out_refs
        s_q = jnp.maximum(jnp.max(jnp.abs(o), axis=-1, keepdims=True),
                          1e-6) / 127.0
        wire_ref[0] = jnp.round(o / s_q).astype(jnp.int8)
        scale_ref[0] = s_q
    else:
        o_ref, lse_ref = out_refs
        o_ref[0] = o
    lse_ref[0] = lse


def paged_decode_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        cl_page: jax.Array, cl_pos: jax.Array,
                        qpos: jax.Array, *, window: int = 0,
                        cap: float = 0.0, encode_wire: bool = False,
                        interpret: bool = False):
    """Fused gather->flash->partial over one pool shard.

    q [B, K1, Hq, dh]; k_pool/v_pool [P_loc, psz, Hkv, dh] (this shard's
    pool slice); cl_page [B, ppc] int32 shard-LOCAL page rows (-1 = no
    page); cl_pos [B, ppc] int32 absolute position of each page's first
    token; qpos [B, K1] int32 absolute per-query positions.

    Returns ``(o [B,K1,Hq,dh] f32, lse [B,K1,Hq] f32)``, or with
    ``encode_wire`` the epilogue-quantized partial ``(wire int8
    [B,K1,Hq,dh], scale f32 [B,K1,Hq,1], lse)`` ready for the coded
    cross-shard combine (``core.boundary.coded_combine_partials``).
    """
    B, K1, Hq, dh = q.shape
    P_loc, psz, Hkv, _ = k_pool.shape
    ppc = cl_page.shape[1]
    scale = 1.0 / math.sqrt(dh)
    pool_spec = pl.BlockSpec((P_loc, psz, Hkv, dh),
                             lambda i: (0, 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, K1, Hq, dh), lambda i: (i, 0, 0, 0)),
        pool_spec, pool_spec,
        pl.BlockSpec((1, ppc), lambda i: (i, 0)),
        pl.BlockSpec((1, ppc), lambda i: (i, 0)),
        pl.BlockSpec((1, K1), lambda i: (i, 0)),
    ]
    lse_shape = jax.ShapeDtypeStruct((B, K1, Hq), F32)
    lse_spec = pl.BlockSpec((1, K1, Hq), lambda i: (i, 0, 0))
    if encode_wire:
        out_shape = (jax.ShapeDtypeStruct((B, K1, Hq, dh), jnp.int8),
                     jax.ShapeDtypeStruct((B, K1, Hq, 1), F32),
                     lse_shape)
        out_specs = (pl.BlockSpec((1, K1, Hq, dh), lambda i: (i, 0, 0, 0)),
                     pl.BlockSpec((1, K1, Hq, 1), lambda i: (i, 0, 0, 0)),
                     lse_spec)
    else:
        out_shape = (jax.ShapeDtypeStruct((B, K1, Hq, dh), F32), lse_shape)
        out_specs = (pl.BlockSpec((1, K1, Hq, dh), lambda i: (i, 0, 0, 0)),
                     lse_spec)
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, window=window,
                          cap=cap, encode_wire=encode_wire),
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(q, k_pool, v_pool, cl_page, cl_pos, qpos)
