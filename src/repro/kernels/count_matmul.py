"""Pallas TPU kernel: spike-count matmul with fused linear decode.

The rate-code decode (paper eq 3) is linear: a_k = counts_k * (scale_k/T).
So the first matmul on the receiving chip can absorb the decode:

    y[m,n] = sum_k  c[m,k] * (scale[k]/T) * W[k,n]

This kernel consumes int8 signed counts straight off the wire — the
decoded bf16 activation tensor never exists in HBM.  MXU-aligned blocks
(multiples of 128 on M/N/K); fp32 accumulation; K-loop innermost in the
grid with accumulate-into-output-block pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _count_matmul_kernel(c_ref, w_ref, scale_ref, o_ref, acc_ref, *,
                         n_k: int, inv_T: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...].astype(jnp.float32)                  # [bm, bk]
    s = scale_ref[...].astype(jnp.float32) * inv_T      # [1, bk]
    w = w_ref[...].astype(jnp.float32)                  # [bk, bn]
    acc_ref[...] += jax.lax.dot_general(
        c * s, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def count_matmul_pallas(counts: jax.Array, w: jax.Array, scale: jax.Array,
                        *, T: int = 15, block_m: int = 256,
                        block_n: int = 256, block_k: int = 512,
                        out_dtype=jnp.bfloat16,
                        interpret: bool = False) -> jax.Array:
    """counts int8 [M, K] x w [K, N] (bf16/f32) -> [M, N] out_dtype.

    scale: per-K-channel decode scale [K].
    """
    M, K = counts.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (counts.shape, w.shape)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_count_matmul_kernel, n_k=n_k, inv_T=1.0 / T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(counts, w, scale.reshape(1, K))
