"""Pallas TPU kernel: 4-bit two-per-byte pack / unpack (EMIO serdes analogue).

For T <= 7 the signed count fits 4 bits after bias (+T => {0..14} < 16),
halving wire bytes again.  The pack is the TPU analogue of the paper's
EMIO serialization stage: a pure layout transform executed at VPU rate so
the collective sees half the bytes.

Layout: last axis split into (C/2, 2); lo | hi<<4.  Blocks [bm, bc] with
bc a multiple of 2*128 lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack4_kernel(x_ref, o_ref):
    x = x_ref[...]
    lo = x[:, 0::2]
    hi = x[:, 1::2]
    o_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)


def _unpack4_kernel(x_ref, o_ref):
    x = x_ref[...]
    lo = x & 0xF
    hi = (x >> 4) & 0xF
    bm, bc = x.shape
    out = jnp.stack([lo, hi], axis=-1).reshape(bm, bc * 2)
    o_ref[...] = out.astype(jnp.uint8)


def pack4_pallas(wire: jax.Array, *, block_m: int = 256,
                 block_c: int = 1024, interpret: bool = False) -> jax.Array:
    """uint8 values < 16, shape [M, C] (C even) -> uint8 [M, C//2]."""
    M, C = wire.shape
    assert C % 2 == 0
    bm, bc = min(block_m, M), min(block_c, C)
    assert M % bm == 0 and C % bc == 0 and bc % 2 == 0
    grid = (M // bm, C // bc)
    return pl.pallas_call(
        _pack4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bc // 2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C // 2), jnp.uint8),
        interpret=interpret,
    )(wire)


def unpack4_pallas(packed: jax.Array, *, block_m: int = 256,
                   block_c: int = 512, interpret: bool = False) -> jax.Array:
    """uint8 [M, C2] -> uint8 [M, 2*C2]."""
    M, C2 = packed.shape
    bm, bc = min(block_m, M), min(block_c, C2)
    assert M % bm == 0 and C2 % bc == 0
    grid = (M // bm, C2 // bc)
    return pl.pallas_call(
        _unpack4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bc * 2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C2 * 2), jnp.uint8),
        interpret=interpret,
    )(packed)
