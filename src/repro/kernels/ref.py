"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are asserted against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lif_encode_ref(x: jax.Array, theta: jax.Array, scale: jax.Array,
                   *, T: int = 15) -> jax.Array:
    """Reference T-tick on/off IF rate encoder -> int8 signed counts."""
    x = x.astype(jnp.float32)
    theta = theta.astype(jnp.float32)
    scale = scale.astype(jnp.float32)
    gate = (jnp.abs(x) >= theta).astype(jnp.float32)
    drive_p = jnp.clip(x / scale, 0.0, 1.0)
    drive_n = jnp.clip(-x / scale, 0.0, 1.0)

    def tick(carry, _):
        up, un, cp, cn = carry
        up = up + drive_p
        un = un + drive_n
        sp = (up >= 1.0).astype(jnp.float32)
        sn = (un >= 1.0).astype(jnp.float32)
        return (up - sp, un - sn, cp + sp, cn + sn), None

    h = jnp.full_like(x, 0.5)
    z = jnp.zeros_like(x)
    (_, _, cp, cn), _ = jax.lax.scan(tick, (h, h, z, z), None, length=T)
    return ((cp - cn) * gate).astype(jnp.int8)


def count_matmul_ref(counts: jax.Array, w: jax.Array, scale: jax.Array,
                     *, T: int = 15, out_dtype=jnp.bfloat16) -> jax.Array:
    """Decode-then-matmul reference: (counts * scale/T) @ w."""
    a = counts.astype(jnp.float32) * (scale.astype(jnp.float32) / T)[None, :]
    y = a @ w.astype(jnp.float32)
    return y.astype(out_dtype)


def paged_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     cl_page: jax.Array, cl_pos: jax.Array, qpos: jax.Array,
                     *, window: int = 0, cap: float = 0.0):
    """Dense single-softmax oracle for the fused paged-decode kernel.

    Same inputs/outputs as ``paged_decode.paged_decode_pallas`` (without
    the wire epilogue); gathers every compacted-list page densely and
    runs the exact masking/softmax math of
    ``models.common.verify_attention_partial`` — one global max, not the
    kernel's online per-page reduction, so agreement is fp-epsilon.
    """
    import math
    B, K1, Hq, dh = q.shape
    P_loc, psz, Hkv, _ = k_pool.shape
    ppc = cl_page.shape[1]
    valid = cl_page >= 0                                     # [B, ppc]
    safe = jnp.where(valid, cl_page, 0)
    k_s = k_pool[safe].astype(jnp.float32)       # [B, ppc, psz, Hkv, dh]
    v_s = v_pool[safe].astype(jnp.float32)
    k_s = k_s.reshape(B, ppc * psz, Hkv, dh)
    v_s = v_s.reshape(B, ppc * psz, Hkv, dh)
    if Hkv != Hq:
        g = Hq // Hkv
        k_s = jnp.repeat(k_s, g, axis=2)
        v_s = jnp.repeat(v_s, g, axis=2)
    k_pos = (cl_pos[:, :, None] + jnp.arange(psz)).reshape(B, ppc * psz)
    ent_ok = jnp.repeat(valid, psz, axis=1)                  # [B, ppc*psz]
    s = jnp.einsum("bqhd,bkhd->bqhk", q.astype(jnp.float32), k_s)
    s = s / math.sqrt(dh)
    if cap:
        s = cap * jnp.tanh(s / cap)
    posb = qpos[:, :, None, None]                            # [B,K1,1,1]
    mask = k_pos[:, None, None, :] <= posb
    if window:
        mask &= (posb - k_pos[:, None, None, :]) < window
    mask &= ent_ok[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqhk,bkhd->bqhd", p, v_s)
    o = o / jnp.maximum(l[..., None], 1e-30)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return o, lse


def pack4_ref(wire: jax.Array) -> jax.Array:
    lo = wire[..., 0::2]
    hi = wire[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack4_ref(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
