"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are asserted against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lif_encode_ref(x: jax.Array, theta: jax.Array, scale: jax.Array,
                   *, T: int = 15) -> jax.Array:
    """Reference T-tick on/off IF rate encoder -> int8 signed counts."""
    x = x.astype(jnp.float32)
    theta = theta.astype(jnp.float32)
    scale = scale.astype(jnp.float32)
    gate = (jnp.abs(x) >= theta).astype(jnp.float32)
    drive_p = jnp.clip(x / scale, 0.0, 1.0)
    drive_n = jnp.clip(-x / scale, 0.0, 1.0)

    def tick(carry, _):
        up, un, cp, cn = carry
        up = up + drive_p
        un = un + drive_n
        sp = (up >= 1.0).astype(jnp.float32)
        sn = (un >= 1.0).astype(jnp.float32)
        return (up - sp, un - sn, cp + sp, cn + sn), None

    h = jnp.full_like(x, 0.5)
    z = jnp.zeros_like(x)
    (_, _, cp, cn), _ = jax.lax.scan(tick, (h, h, z, z), None, length=T)
    return ((cp - cn) * gate).astype(jnp.int8)


def count_matmul_ref(counts: jax.Array, w: jax.Array, scale: jax.Array,
                     *, T: int = 15, out_dtype=jnp.bfloat16) -> jax.Array:
    """Decode-then-matmul reference: (counts * scale/T) @ w."""
    a = counts.astype(jnp.float32) * (scale.astype(jnp.float32) / T)[None, :]
    y = a @ w.astype(jnp.float32)
    return y.astype(out_dtype)


def pack4_ref(wire: jax.Array) -> jax.Array:
    lo = wire[..., 0::2]
    hi = wire[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack4_ref(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
