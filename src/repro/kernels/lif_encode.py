"""Pallas TPU kernel: fused T-tick spike rate encoder (paper Fig 4a, eq 2).

The paper's CLP converter accumulates the (normalized) activation into a
membrane each tick and fires on threshold crossing — an integrate-and-
fire rate coder.  Done naively this materializes a [T, M, C] spike train
in HBM; the fused kernel keeps the membranes and running counts in
VMEM/VREGs and emits only the int8 signed count — an O(T) -> O(1)
HBM-traffic collapse.

Signed activations use on/off IF populations (DESIGN.md §2); the wire
value is the count difference in {-T..T} stored int8.  A learnable
per-channel firing gate theta silences weak channels (the learned
sparsity, eq 10's knob).  With membrane init 0.5, the T-tick count is
bit-identical to the closed-form encoder round(clip(|x|/scale,0,1)*T).

Block layout: grid (M/bm, C/bc); x tile [bm, bc] resident in VMEM for the
whole tick loop; theta/scale tiles [1, bc] broadcast along rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_encode_kernel(x_ref, theta_ref, scale_ref, out_ref, *, T: int):
    x = x_ref[...].astype(jnp.float32)
    theta = theta_ref[...].astype(jnp.float32)          # [1, bc]
    scale = scale_ref[...].astype(jnp.float32)          # [1, bc]
    gate = (jnp.abs(x) >= theta).astype(jnp.float32)
    drive_p = jnp.clip(x / scale, 0.0, 1.0)
    drive_n = jnp.clip(-x / scale, 0.0, 1.0)

    def tick(_, carry):
        up, un, cp, cn = carry
        up = up + drive_p
        un = un + drive_n
        sp = (up >= 1.0).astype(jnp.float32)
        sn = (un >= 1.0).astype(jnp.float32)
        return up - sp, un - sn, cp + sp, cn + sn

    h = jnp.full_like(x, 0.5)
    z = jnp.zeros_like(x)
    _, _, cp, cn = jax.lax.fori_loop(0, T, tick, (h, h, z, z))
    out_ref[...] = ((cp - cn) * gate).astype(jnp.int8)


def lif_encode_pallas(x: jax.Array, theta: jax.Array, scale: jax.Array,
                      *, T: int = 15,
                      block_m: int = 256, block_c: int = 512,
                      interpret: bool = False) -> jax.Array:
    """x [M, C] float -> int8 signed counts [M, C].

    theta, scale: per-channel [C].  M % block_m == 0, C % block_c == 0
    (callers pad; ops.py handles ragged shapes).
    """
    M, C = x.shape
    bm, bc = min(block_m, M), min(block_c, C)
    assert M % bm == 0 and C % bc == 0, (x.shape, bm, bc)
    grid = (M // bm, C // bc)
    return pl.pallas_call(
        functools.partial(_lif_encode_kernel, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C), jnp.int8),
        interpret=interpret,
    )(x, theta.reshape(1, C), scale.reshape(1, C))
