"""Batched serving: continuous batching, block-table paged KV (shared
device page pool), on-device sampling, self-drafting speculative
decoding, and async dispatch/commit decode streams over the
spike-coded wire.

``EngineConfig`` knobs (the four that shape the serving regime):

===============  ========================================================
``async_depth``  Decode steps the host may dispatch ahead of the oldest
                 un-synced step.  0 (default): classic synchronous loop.
                 1: step t+1 launches before step t's tokens are fetched
                 — host scheduling overlaps device compute; greedy
                 streams are token-identical to 0 (fuzz-enforced).
                 With ``spec_k > 0`` drafting joins the pipeline, so
                 only admission prefill overlaps the in-flight verify.
``spec_k``       Draft tokens per speculative verify step (0: vanilla
                 decode).  One batched forward scores all spec_k+1
                 positions per slot through the same coded boundaries;
                 greedy acceptance is token-identical to ``spec_k=0``.
                 Recurrent-state families force 0 (no rollback).
``num_pages``    KV page-pool size, independent of ``num_slots *
                 max_seq``.  0: dense-equivalent default (can never
                 exhaust before the slots do); smaller is the paging
                 payoff — slots share the pool, exhaustion is the typed
                 ``PagePoolExhausted``.
``page_size``    Positions per KV page.  Admission maps only
                 ``ceil(prompt_len / page_size)`` pages; decode maps one
                 more page per ``page_size`` generated tokens
                 (alloc-on-extend).
===============  ========================================================
"""
from .draft import NGramDrafter
from .engine import (WARMUP_RID, EngineConfig, Request, ServingEngine,
                     make_engine_decode_step, make_engine_prefill_step,
                     make_engine_verify_step)
from .errors import (CacheOverflowError, EngineConfigError,
                     PagePoolExhausted, SchedulerStall, SlotsExhausted)
from .kv_cache import PagedKVCache, SlotAllocator
from .sampling import SamplingConfig, sample, sample_verify

__all__ = ["CacheOverflowError", "EngineConfig", "EngineConfigError",
           "NGramDrafter", "PagePoolExhausted", "PagedKVCache", "Request",
           "SamplingConfig", "SchedulerStall", "ServingEngine",
           "SlotAllocator", "SlotsExhausted", "WARMUP_RID", "sample",
           "sample_verify", "make_engine_decode_step",
           "make_engine_prefill_step", "make_engine_verify_step"]
