"""Batched serving: continuous batching, block-table paged KV (shared
device page pool), on-device sampling, and self-drafting speculative
decoding over the spike-coded wire."""
from .draft import NGramDrafter
from .engine import (WARMUP_RID, EngineConfig, Request, ServingEngine,
                     make_engine_decode_step, make_engine_prefill_step,
                     make_engine_verify_step)
from .errors import (CacheOverflowError, EngineConfigError,
                     PagePoolExhausted, SchedulerStall, SlotsExhausted)
from .kv_cache import PagedKVCache, SlotAllocator
from .sampling import SamplingConfig, sample, sample_verify

__all__ = ["CacheOverflowError", "EngineConfig", "EngineConfigError",
           "NGramDrafter", "PagePoolExhausted", "PagedKVCache", "Request",
           "SamplingConfig", "SchedulerStall", "ServingEngine",
           "SlotAllocator", "SlotsExhausted", "WARMUP_RID", "sample",
           "sample_verify", "make_engine_decode_step",
           "make_engine_prefill_step", "make_engine_verify_step"]
