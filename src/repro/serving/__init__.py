"""Batched serving: continuous batching, block-table paged KV (shared
device page pool), on-device sampling, self-drafting speculative
decoding, async dispatch/commit decode streams over the spike-coded
wire, and an SLO harness (trace-driven workloads, fault injection,
BENCH_serve.json perf trajectory).

``EngineConfig`` knobs (the ones that shape the serving regime):

===============  ========================================================
``async_depth``  Decode steps the host may dispatch ahead of the oldest
                 un-synced step.  0 (default): classic synchronous loop.
                 1: step t+1 launches before step t's tokens are fetched
                 — host scheduling overlaps device compute; greedy
                 streams are token-identical to 0 (fuzz-enforced).
                 With ``spec_k > 0`` drafting joins the pipeline, so
                 only admission prefill overlaps the in-flight verify.
``spec_k``       Draft tokens per speculative verify step (0: vanilla
                 decode).  One batched forward scores all spec_k+1
                 positions per slot through the same coded boundaries;
                 greedy acceptance is token-identical to ``spec_k=0``.
                 Recurrent-state families force 0 (no rollback).
``drafter``      Who proposes those spec_k tokens.  ``"ngram"``
                 (default): host-side prompt-lookup over each slot's
                 committed history (``NGramDrafter``) — free, but the
                 host must see step t's tokens before it can draft step
                 t+1, so ``async_depth`` can only overlap admission
                 prefill.  ``"heads"``: learned draft heads
                 (``models.draft_heads``; train via
                 ``examples/train_hnn_lm.py --draft-heads``) riding the
                 verify step itself — acceptance, correction and the
                 next step's drafts are all computed on device, the
                 verify feed chains device-to-device, and verify
                 dispatches pipeline under ``async_depth > 0`` with NO
                 host join between them.  Needs a ``"draft_heads"``
                 subtree in params (typed ``EngineConfigError``
                 otherwise) with at least ``spec_k`` heads.  Both
                 drafters are greedy-token-identical to ``spec_k=0``
                 (fuzz-enforced across drafter x spec_k x async_depth x
                 codec x disagg).
``num_pages``    KV page-pool size, independent of ``num_slots *
                 max_seq``.  0: dense-equivalent default (can never
                 exhaust before the slots do); smaller is the paging
                 payoff — slots share the pool, exhaustion is the typed
                 ``PagePoolExhausted``.
``page_size``    Positions per KV page.  Admission maps only
                 ``ceil(prompt_len / page_size)`` pages; decode maps one
                 more page per ``page_size`` generated tokens
                 (alloc-on-extend).
``attn_kernel``  Decode/verify attention path.  ``"fused"`` (default):
                 the Pallas kernel walks the allocator's compacted
                 per-shard page lists — page gather, online-softmax
                 flash decode and the int8 wire epilogue in ONE kernel,
                 no ``[B, pages*page_size, Hkv, dh]`` gather in HBM, per
                 shard cost ``ceil(len / (page_size * tp))`` pages
                 instead of the full block-table width.  ``"reference"``:
                 the dense gather + ``verify_attention_partial`` path —
                 the oracle the kernel is fuzz-checked against
                 (token-identical greedy streams, enforced in
                 tests/test_paged_decode.py).  Anything else is a typed
                 ``EngineConfigError``.
``preempt``      Graceful degradation under pool pressure (default on):
                 a mid-flight ``PagePoolExhausted`` drains the pipeline
                 (limbo pages rejoin the pool) and then evicts +
                 re-queues the YOUNGEST slot of the starving group,
                 restarting it from scratch on re-admit — greedy streams
                 stay bit-identical to an uninterrupted run
                 (fuzz-enforced), so only latency pays.  False: the
                 typed error propagates to the caller's own policy.
``disagg``       Disaggregated prefill/decode (default off; needs a
                 dp >= 2 mesh).  The first ``prefill_groups`` dp groups
                 own admission prefill; the rest own decode.  Each
                 admitted request's paged KV (and any recurrent-state
                 rows) migrates to its decode group in ONE ppermute onto
                 pages the decode group mapped at matching per-shard
                 positions; admission pre-checks BOTH sides (slot, pages,
                 mirrored placement) so a started prefill can never
                 strand.  Greedy streams are token-identical to the
                 colocated engine (fuzz-enforced across spec_k x
                 async_depth x codec x kv_wire).
``prefill_groups``  How many dp groups ``disagg`` reserves for prefill
                 (default 1; must leave >= 1 decode group).
``kv_wire``      Migration wire format: ``"fp"`` moves KV pages at pool
                 dtype; ``"coded"`` moves per-page pow2-absmax int8
                 (~0.3x the bytes at dh=16) whose power-of-two scales
                 make encode/decode exactly idempotent on the pool — so
                 the coded wire is also token-identical, not just close
                 (see ``repro.core.boundary.coded_kv_migrate``).
``router``       Decode-group choice per migration: ``"load"`` (default)
                 picks the group with the fewest pages in use + limbo
                 (ties to the lowest id), ``"rr"`` round-robins over
                 mirror-capable groups.
===============  ========================================================

SLO harness knobs (``repro.serving.workload`` / ``repro.serving.slo``):

==================  =====================================================
``RequestClass``    One tenant's traffic model: ``poisson`` or bursty
                    ``onoff`` arrivals at ``rate`` req/s, prompt/gen
                    length ranges, a long-context ``tail_p``/``tail_len``
                    minority, temperature.
``PRESETS``         Named trace mixes (``steady`` / ``bursty`` /
                    ``longtail`` / ``multitenant``) scaled to the engine
                    budget; ``replay`` drives an engine through a trace
                    on a deterministic logical clock (or wall clock).
``SLOTargets``      Per-request TTFT/TPOT targets the attainment numbers
                    in ``SLOMonitor.report()`` are judged against.
``FaultPlan``       Seeded per-tick fault probabilities (``p_preempt``,
                    ``p_replica_loss``, ``p_suspend``) the
                    ``FaultInjector`` rolls once per tick — same seed,
                    same faults, so identity tests replay exactly.
``wire_streams_     ``SLOMonitor`` pricing table: step kind -> per-
per_step``          collective {stream -> bytes} of one compiled step,
                    from ``engine.wire_stream_profile()`` (psum / head
                    all-gather / partial combine / kv-migrate, parsed
                    out of the step HLO).  Every tick then records a
                    ``wire_streams`` split summing to its scalar
                    ``wire_bytes``; unknown step kinds warn instead of
                    silently pricing at 0, and migration bytes pending
                    at drain flush into a terminal ``drain`` event.
``--cosim``         ``serve_bench`` / ``slo_bench`` flag: feed each
                    run's step trace through the cycle-level NoC
                    simulator (``repro.sim.noc.NocSim.simulate_trace``)
                    — per-codec ``cosim`` block (simulated joules/token,
                    NoC cycles/us per token, PE/MEM/Router/EMIO energy,
                    per-stream wire KB) in BENCH_serve.json, plus a
                    codec ranking by simulated joules per served token.
                    Schema-gated by ``validate_bench``, which also
                    enforces cycle-level >= closed-form eq (8) EMIO.
==================  =====================================================
"""
from .draft import NGramDrafter
from .engine import (WARMUP_RID, EngineConfig, Request, ServingEngine,
                     make_engine_decode_step, make_engine_heads_verify_step,
                     make_engine_prefill_step, make_engine_verify_step)
from .errors import (CacheOverflowError, EngineConfigError,
                     PagePoolExhausted, SchedulerStall, SlotsExhausted)
from .kv_cache import PagedKVCache, SlotAllocator
from .sampling import SamplingConfig, sample, sample_verify
from .slo import (BENCH_SCHEMA, FaultInjector, FaultPlan, SLOMonitor,
                  SLOTargets, load_bench, make_bench_payload,
                  validate_bench, write_bench)
from .workload import (PRESETS, RequestClass, Trace, TracedRequest,
                       make_trace, preset_trace, replay, zoo_mix)

__all__ = ["BENCH_SCHEMA", "CacheOverflowError", "EngineConfig",
           "EngineConfigError", "FaultInjector", "FaultPlan",
           "NGramDrafter", "PRESETS", "PagePoolExhausted", "PagedKVCache",
           "Request", "RequestClass", "SLOMonitor", "SLOTargets",
           "SamplingConfig", "SchedulerStall", "ServingEngine",
           "SlotAllocator", "SlotsExhausted", "Trace", "TracedRequest",
           "WARMUP_RID", "load_bench", "make_bench_payload", "make_trace",
           "preset_trace", "replay", "sample", "sample_verify",
           "validate_bench", "write_bench", "zoo_mix",
           "make_engine_decode_step", "make_engine_heads_verify_step",
           "make_engine_prefill_step", "make_engine_verify_step"]
