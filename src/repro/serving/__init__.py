"""Batched serving: continuous batching, paged KV, on-device sampling."""
from .engine import (EngineConfig, Request, ServingEngine,
                     make_engine_decode_step, make_engine_prefill_step)
from .kv_cache import PagedKVCache, SlotAllocator
from .sampling import SamplingConfig, sample

__all__ = ["EngineConfig", "Request", "ServingEngine", "PagedKVCache",
           "SlotAllocator", "SamplingConfig", "sample",
           "make_engine_decode_step", "make_engine_prefill_step"]
