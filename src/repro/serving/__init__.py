"""Batched serving: continuous batching, paged KV, on-device sampling,
and self-drafting speculative decoding over the spike-coded wire."""
from .draft import NGramDrafter
from .engine import (WARMUP_RID, EngineConfig, EngineConfigError, Request,
                     SchedulerStall, ServingEngine, make_engine_decode_step,
                     make_engine_prefill_step, make_engine_verify_step)
from .kv_cache import PagedKVCache, SlotAllocator
from .sampling import SamplingConfig, sample, sample_verify

__all__ = ["EngineConfig", "EngineConfigError", "NGramDrafter", "Request",
           "SchedulerStall", "ServingEngine", "PagedKVCache",
           "SlotAllocator", "SamplingConfig", "WARMUP_RID", "sample",
           "sample_verify", "make_engine_decode_step",
           "make_engine_prefill_step", "make_engine_verify_step"]
