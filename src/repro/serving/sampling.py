"""On-device sampling from tp-sharded logits (inside shard_map).

The serving engine never gathers the [B, V] logits to the host: the
next token is computed where the logits live, from each rank's local
vocab shard, using psum/pmax/pmin over the tensor axis.

Everything is strictly per-slot (no reduction mixes batch rows), so
greedy decoding is bit-identical across batch compositions; stochastic
draws are per-slot independent but tied to the slot row + key, so they
reproduce only under a fixed schedule.

Methods (all fused into one kernel; per-slot ``temps`` selects):
  temps[i] == 0 : greedy (distributed argmax)
  temps[i] >  0 : temperature softmax via the Gumbel-max trick, with
                  optional static top-k / top-p (nucleus) masking.

top-k uses a per-rank ``lax.top_k`` + an all_gather of tp*k candidate
values to find the global k-th logit.  top-p bisects the probability
threshold (24 halvings) with a psum'd kept-mass query per step — exact
to ~6e-8 in cumulative probability, no global sort required.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Static sampling controls compiled into the decode step.

    ``top_k``/``top_p`` of 0 disable the respective filter.  Per-slot
    temperature is a dynamic input (0 = greedy for that slot).
    """

    top_k: int = 0
    top_p: float = 0.0


def dist_argmax(vals, tp, tp_size):
    """Global argmax over a tp-sharded last axis -> global index [B]."""
    lmax = jnp.max(vals, axis=-1)
    lidx = jnp.argmax(vals, axis=-1).astype(jnp.int32)
    if tp_size == 1:
        return lidx
    V_loc = vals.shape[-1]
    off = lax.axis_index(tp).astype(jnp.int32) * V_loc
    gmax = lax.pmax(lmax, tp)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    cand = jnp.where(lmax >= gmax, lidx + off, big)
    return lax.pmin(cand, tp)                       # ties -> lowest id


def _apply_top_k(lt, k, tp, tp_size):
    V_loc = lt.shape[-1]
    k_loc = min(k, V_loc)
    tv = lax.top_k(lt, k_loc)[0]                    # [B, k_loc]
    if tp_size > 1:
        tv = lax.all_gather(tv, tp, axis=1, tiled=True)  # [B, tp*k_loc]
    kk = min(k, tv.shape[-1])
    thr = lax.top_k(tv, kk)[0][:, -1:]              # global k-th value
    return jnp.where(lt < thr, -jnp.inf, lt)


def _apply_top_p(lt, p, tp, tp_size):
    m = jnp.max(lt, axis=-1, keepdims=True)
    if tp_size > 1:
        m = lax.pmax(m, tp)
    e = jnp.exp(lt - m)
    se = jnp.sum(e, axis=-1, keepdims=True)
    if tp_size > 1:
        se = lax.psum(se, tp)
    probs = e / se

    def kept_mass(thr):
        mass = jnp.sum(jnp.where(probs >= thr, probs, 0.0), axis=-1,
                       keepdims=True)
        return lax.psum(mass, tp) if tp_size > 1 else mass

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ge = kept_mass(mid) >= p                    # still a valid nucleus
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    # largest threshold whose kept set still holds >= p probability mass
    lo, _ = lax.fori_loop(0, 24, body,
                          (jnp.zeros_like(m), jnp.ones_like(m)))
    return jnp.where(probs >= lo, lt, -jnp.inf)


def sample(logits_local, key, temps, *, tp, tp_size,
           cfg: SamplingConfig | None = None):
    """Next tokens [B] (global vocab ids) from local logits [B, V_loc].

    Must be called inside shard_map when ``tp_size > 1`` (``tp`` is the
    bound tensor-axis name).  ``key`` is a uint32[2] PRNG key replicated
    across ranks; noise is decorrelated per rank by folding in the rank
    index, and is per-slot independent by construction.
    """
    cfg = cfg or SamplingConfig()
    logits = logits_local.astype(jnp.float32)
    greedy = dist_argmax(logits, tp, tp_size)

    t = jnp.maximum(temps, 1e-6).astype(jnp.float32)[:, None]
    lt = logits / t
    if cfg.top_k > 0:
        lt = _apply_top_k(lt, cfg.top_k, tp, tp_size)
    if 0.0 < cfg.top_p < 1.0:
        lt = _apply_top_p(lt, cfg.top_p, tp, tp_size)
    gkey = key
    if tp_size > 1:
        gkey = jax.random.fold_in(key, lax.axis_index(tp))
    gz = jax.random.gumbel(gkey, lt.shape, jnp.float32)
    stoch = dist_argmax(lt + gz, tp, tp_size)

    return jnp.where(temps > 0, stoch, greedy).astype(jnp.int32)


def sample_verify(logits_local, key, temps, *, tp, tp_size,
                  cfg: SamplingConfig | None = None):
    """Vectorized accept-sampling over K1 verify positions.

    logits_local [B, K1, V_loc] (one row per speculative position) ->
    tokens [B, K1].  Flattens the position axis into the slot axis so
    every position goes through exactly the same fused kernel as a
    vanilla decode step: under greedy (temps == 0) column j is the
    bit-exact argmax a vanilla step would produce after committing
    tokens[:, :j+1], which is what makes greedy speculative decoding
    token-identical to spec_k=0.  Stochastic positions draw independent
    per-(slot, position) Gumbel noise, so each accepted token is still an
    exact draw from its committed-prefix conditional.
    """
    B, K1, V_loc = logits_local.shape
    flat = logits_local.reshape(B * K1, V_loc)
    temps_f = jnp.repeat(temps, K1)
    tok = sample(flat, key, temps_f, tp=tp, tp_size=tp_size, cfg=cfg)
    return tok.reshape(B, K1)
