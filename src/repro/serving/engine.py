"""Continuous-batching serving engine over the spike-coded decode path.

One ``ServingEngine`` owns a fixed pool of request slots (the decode
batch), a slot-major ``PagedKVCache``, and up to four compiled programs:

  prefill : B=1, fixed-length right-padded prompt -> slot-shaped cache
            + the first sampled token (logits taken at the true last
            prompt position via ``last_pos``)
  insert  : splice the prefilled cache into a free slot (donated)
  decode  : ONE step for ALL slots at once — per-slot positions,
            per-slot temperatures, fused distributed sampling — with the
            cache donated so serving is allocation-free at steady state
  verify  : (``spec_k > 0``) the speculative sibling of decode — scores
            K1 = spec_k+1 positions per slot in one batched forward
            (last committed token + spec_k draft tokens from the
            deterministic prompt-lookup drafter), writes KV for all of
            them, and returns K1 sampled tokens per slot.  The scheduler
            keeps the longest draft prefix matching the verify output
            plus the first correction token, then rolls the rejected
            tail's cache occupancy back (``PagedKVCache.rollback``).
            Greedy spec decoding is token-identical to ``spec_k=0``
            (asserted by tests/dist_scenarios.py ``serving_spec_parity``);
            the k-fold decode-boundary traffic of the verify step rides
            the same coded collectives, which is exactly the workload
            the spike wire makes cheap.  Families with recurrent state
            fall back to ``spec_k=0`` — their state cannot roll back.

Scheduling is classic continuous batching: every ``step()`` first admits
queued requests into free slots (prefill-then-decode interleaving), then
runs a single batched decode step; finished requests (max tokens, EOS,
or context full) retire immediately and their slot returns to the free
list for the next admit.

Every decode-path activation collective carries the spike/int8 wire
(``repro.core.boundary.coded_psum`` / ``wire_roundtrip``); the only fp
collectives left on the step are head-space layout exchanges (q/kv head
gathers) and the flash-decode LSE combine, which carry O(heads) metadata
rather than D-space activations.

All per-slot computation is batch-independent — no reduction mixes
slots, int8 scales are per-token — so under greedy decoding a slot's
token stream is bit-identical whether it shares the batch with 0 or
``num_slots-1`` neighbours (asserted by tests/dist_scenarios.py
``serving_parity``).  Stochastic sampling is per-slot independent in
distribution, but draws its Gumbel noise from the slot row and the
engine's step counter, so sampled streams are reproducible only for a
fixed schedule, not across different batch compositions.

Correctness note on padded prefill: right-padding is exact for
attention-family models (pad KV beyond ``last_pos`` is masked by the
per-slot position and overwritten as decode advances).  Families with
recurrent state (ssm/rnn/hybrid) fold pad tokens into the prefill-final
state, so their prompts must arrive at exactly ``prefill_len`` tokens;
the engine enforces this.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ShapeCell
from ..launch.serve import strip_dp_specs
from ..launch.specs import (cache_specs, make_context, make_plan,
                            serve_decode_input_specs,
                            serve_verify_input_specs, verify_shape_cell)
from ..launch.train import shard_params_specs
from ..models import model as M
from . import sampling
from .draft import NGramDrafter
from .kv_cache import PagedKVCache
from .sampling import SamplingConfig


class EngineConfigError(ValueError):
    """Unserveable engine configuration (bad mesh/shape/family combo).

    Raised from ``ServingEngine.__init__`` instead of ``assert`` so the
    checks survive ``python -O``.
    """


class SchedulerStall(RuntimeError):
    """``run`` exhausted ``max_steps`` with requests still in flight."""


#: Reserved request id for ``warmup``'s throwaway request.  A fresh
#: ``object()`` compares equal only to itself, so no user-supplied rid
#: (int, str, uuid, ...) can ever collide with it in a results dict.
WARMUP_RID = object()


@dataclasses.dataclass
class Request:
    """One generation request."""

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 4
    max_seq: int = 128
    prefill_len: int = 0           # 0 -> max_seq
    page_size: int = 64
    top_k: int = 0
    top_p: float = 0.0
    eos_id: Optional[int] = None
    replicate_weights: bool = False
    seed: int = 0
    spec_k: int = 0                # draft tokens per verify step (0: off)


@dataclasses.dataclass
class _Slot:
    req: Request
    out: list
    drafter: Optional[NGramDrafter] = None


def make_engine_prefill_step(cfg, plan, mesh, scfg: SamplingConfig,
                             replicate_weights=False):
    """prefill(params, tokens[1,S], last_pos[1], temp[1], key) ->
    (first_token [1], cache)."""
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "prefill")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, cspecs = cache_specs(plan)

    def step(params, tokens, last_pos, temp, key):
        logits, caches = M.forward_prefill(params, {"tokens": tokens}, ctx,
                                           last_pos=last_pos)
        tok = sampling.sample(logits, key, temp, tp=ctx.tp,
                              tp_size=ctx.tp_size, cfg=scfg)
        return tok, caches

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P(None, plan.tp), P(None), P(None), P()),
        out_specs=(P(None), cspecs), check_vma=False)
    return jax.jit(fn)


def make_engine_decode_step(cfg, plan, mesh, scfg: SamplingConfig,
                            replicate_weights=False):
    """decode(params, cache, token[B], pos[B], temp[B], key) ->
    (next_token [B], cache) — cache donated."""
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, ispecs = serve_decode_input_specs(plan)

    def step(params, cache, token, pos, temp, key):
        logits, cache = M.forward_decode(params, cache, token, pos, ctx)
        tok = sampling.sample(logits, key, temp, tp=ctx.tp,
                              tp_size=ctx.tp_size, cfg=scfg)
        return tok, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


def make_engine_verify_step(cfg, plan, mesh, scfg: SamplingConfig, spec_k,
                            replicate_weights=False):
    """verify(params, cache, tokens[B,K1], pos[B], temp[B], key) ->
    (tokens_out [B,K1], cache) — cache donated.

    One batched forward over all K1 = spec_k+1 speculative positions of
    every slot; column j of ``tokens_out`` is the model's (greedy or
    sampled) next token after committing ``tokens[:, :j+1]``.
    """
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, ispecs = serve_verify_input_specs(plan, spec_k)

    def step(params, cache, tokens, pos, temp, key):
        logits, cache = M.forward_verify(params, cache, tokens, pos, ctx)
        tok = sampling.sample_verify(logits, key, temp, tp=ctx.tp,
                                     tp_size=ctx.tp_size, cfg=scfg)
        return tok, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


_RECURRENT_CACHE_KEYS = ("ssm_state", "rnn_state", "rwkv_state")


class ServingEngine:
    """Batched continuous-batching decode over a slot pool."""

    def __init__(self, cfg, mesh, params, ecfg: EngineConfig):
        if cfg.is_encdec:
            raise EngineConfigError("encoder-decoder serving: follow-on")
        self.cfg, self.mesh, self.params, self.ecfg = cfg, mesh, params, ecfg
        prefill_len = ecfg.prefill_len or ecfg.max_seq
        cell_dec = ShapeCell("serve_decode", ecfg.max_seq, ecfg.num_slots,
                             "decode")
        self.plan = make_plan(cfg, cell_dec, mesh)
        if not self.plan.batch_sharded:
            raise EngineConfigError(
                f"num_slots={ecfg.num_slots} must divide over the data axes "
                f"(dp_size={self.plan.dp_size})")
        if ecfg.max_seq % self.plan.tp_size != 0:
            raise EngineConfigError(
                f"max_seq={ecfg.max_seq} must be divisible by "
                f"tp_size={self.plan.tp_size}")
        if prefill_len % self.plan.tp_size != 0:
            raise EngineConfigError(
                f"prefill_len={prefill_len} must be divisible by "
                f"tp_size={self.plan.tp_size}")
        if ecfg.spec_k < 0:
            raise EngineConfigError(f"spec_k={ecfg.spec_k} must be >= 0")
        cell_pre = ShapeCell("serve_admit", prefill_len, 1, "prefill")
        self.plan_pre = make_plan(cfg, cell_pre, mesh)
        self.prefill_len = prefill_len
        self._has_state = any(
            k in _RECURRENT_CACHE_KEYS
            for pos in cache_specs(self.plan)[0].values() for k in pos)
        # recurrent state folds every token in and cannot roll back a
        # rejected draft: those families serve vanilla (spec_k=0)
        self.spec_k = 0 if self._has_state else ecfg.spec_k

        scfg = SamplingConfig(top_k=ecfg.top_k, top_p=ecfg.top_p)
        self._prefill = make_engine_prefill_step(
            cfg, self.plan_pre, mesh, scfg, ecfg.replicate_weights)
        self._decode = make_engine_decode_step(
            cfg, self.plan, mesh, scfg, ecfg.replicate_weights)
        self._verify = None
        if self.spec_k > 0:
            self.plan_ver = make_plan(
                cfg, verify_shape_cell(ecfg.max_seq, ecfg.num_slots,
                                       self.spec_k), mesh)
            self._verify = make_engine_verify_step(
                cfg, self.plan_ver, mesh, scfg, self.spec_k,
                ecfg.replicate_weights)
        self.cache = PagedKVCache(self.plan, self.plan_pre, mesh,
                                  ecfg.page_size)

        n = ecfg.num_slots
        self._tokens = np.zeros(n, np.int32)
        self._pos = np.zeros(n, np.int32)
        self._temp = np.zeros(n, np.float32)
        self._slots: list[Optional[_Slot]] = [None] * n
        self._queue: deque[Request] = deque()
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._tick = 0
        self.tokens_generated = 0
        self.decode_steps = 0
        self.spec_commits = 0      # tokens committed by verify steps
        self.spec_verifies = 0     # (slot, verify-step) participations

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request):
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admit always "
                             "samples one token from the prefill logits)")
        P_len = len(req.prompt)
        if not 0 < P_len <= self.prefill_len:
            raise ValueError(
                f"prompt len {P_len} not in (0, {self.prefill_len}]")
        if self._has_state and P_len != self.prefill_len:
            raise ValueError(
                "recurrent-state families need prompt_len == prefill_len "
                f"({self.prefill_len}); right-padding would corrupt the "
                "prefill-final state")
        self._queue.append(req)

    def _next_key(self):
        self._tick += 1
        return jax.random.fold_in(self._key, self._tick)

    def _admit(self, req: Request, finished: list):
        P_len = len(req.prompt)
        toks = np.zeros((1, self.prefill_len), np.int32)
        toks[0, :P_len] = np.asarray(req.prompt, np.int32)
        first, pre_cache = self._prefill(
            self.params, toks, np.array([P_len - 1], np.int32),
            np.array([req.temperature], np.float32), self._next_key())
        # occupancy counts cache positions written: the prompt now, the
        # generated tokens as each decode step lands them (extend below)
        slot = self.cache.admit(pre_cache, P_len)
        first = int(np.asarray(first)[0])
        drafter = None
        if self.spec_k > 0:
            drafter = NGramDrafter(list(req.prompt) + [first])
        self._slots[slot] = _Slot(req, [first], drafter)
        self._tokens[slot] = first
        self._pos[slot] = P_len
        self._temp[slot] = req.temperature
        self.tokens_generated += 1
        self._maybe_retire(slot, first, finished)

    def _maybe_retire(self, slot: int, tok: int, finished: list):
        st = self._slots[slot]
        done = (len(st.out) >= st.req.max_new_tokens
                or (self.ecfg.eos_id is not None and tok == self.ecfg.eos_id)
                or self._pos[slot] >= self.ecfg.max_seq)
        if done:
            self.cache.evict(slot)
            self._slots[slot] = None
            finished.append((st.req, st.out))

    # -- scheduling --------------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def idle(self) -> bool:
        return not self._queue and self.num_active == 0

    def step(self) -> list:
        """Admit what fits, then one batched decode (or k-token verify)
        step.  Returns the requests finished this step as
        (request, tokens) pairs."""
        finished: list = []
        while self._queue and self.cache.allocator.num_free:
            self._admit(self._queue.popleft(), finished)
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return finished
        if self.spec_k > 0:
            self._spec_step(active, finished)
            return finished
        nxt, self.cache.buffers = self._decode(
            self.params, self.cache.buffers, self._tokens, self._pos,
            self._temp, self._next_key())
        nxt = np.asarray(nxt)
        self.decode_steps += 1
        for i in active:
            tok = int(nxt[i])
            self._slots[i].out.append(tok)
            self._tokens[i] = tok
            self._pos[i] += 1
            self.cache.allocator.extend(i)
            self.tokens_generated += 1
            self._maybe_retire(i, tok, finished)
        return finished

    def _spec_step(self, active, finished):
        """One speculative step: draft k per slot, verify all k+1
        positions in one batched forward, commit the longest accepted
        prefix plus the model's correction token, roll back the rest.

        Under greedy sampling the committed stream is token-identical to
        ``spec_k=0``: drafts only ever get accepted when they equal the
        argmax the vanilla step would have produced, and the first
        correction token is that argmax itself.
        """
        k = self.spec_k
        n = self.ecfg.num_slots
        drafts = np.zeros((n, k), np.int32)
        for i in active:
            drafts[i] = self._slots[i].drafter.propose(k)
        tok_in = np.concatenate([self._tokens[:, None], drafts], axis=1)
        out, self.cache.buffers = self._verify(
            self.params, self.cache.buffers, tok_in, self._pos,
            self._temp, self._next_key())
        out = np.asarray(out)                                  # [n, k+1]
        self.decode_steps += 1
        for i in active:
            st = self._slots[i]
            # the verify step wrote KV at pos..pos+k; account them all,
            # then roll the rejected tail back once acceptance is known
            self.cache.allocator.extend(i, k + 1)
            a = 0
            while a < k and drafts[i, a] == out[i, a]:
                a += 1
            committed = 0
            for j in range(a + 1):                 # accepted drafts + fixup
                tok = int(out[i, j])
                st.out.append(tok)
                st.drafter.extend([tok])
                self._tokens[i] = tok
                self._pos[i] += 1
                self.tokens_generated += 1
                committed += 1
                if (len(st.out) >= st.req.max_new_tokens
                        or (self.ecfg.eos_id is not None
                            and tok == self.ecfg.eos_id)
                        or self._pos[i] >= self.ecfg.max_seq):
                    break
            self.cache.rollback(i, int(self._pos[i]))
            self.spec_commits += committed
            self.spec_verifies += 1
            self._maybe_retire(i, int(self._tokens[i]), finished)

    @property
    def mean_accepted_len(self) -> float:
        """Mean tokens committed per (slot, verify-step) — >1.0 means the
        drafter is paying for itself."""
        return self.spec_commits / max(self.spec_verifies, 1)

    def run(self, requests: Sequence[Request], max_steps: int = 100000):
        """Serve ``requests`` to completion; {rid: generated tokens}."""
        for r in requests:
            self.submit(r)
        results = {}
        for _ in range(max_steps):
            for req, out in self.step():
                results[req.rid] = out
            if self.idle:
                break
        if not self.idle:
            raise SchedulerStall(
                f"run: {self.num_active} slots still active and "
                f"{len(self._queue)} requests queued after "
                f"{max_steps} steps")
        return results

    def warmup(self, prompt: Sequence[int]):
        """Compile the prefill/insert/decode/verify programs off the
        clock by serving one throwaway request, then zero the throughput
        stats.  The throwaway uses the reserved ``WARMUP_RID`` sentinel,
        which no user-supplied rid can equal."""
        self.run([Request(rid=WARMUP_RID, prompt=prompt, max_new_tokens=2)])
        self.reset_stats()

    def reset_stats(self):
        self.tokens_generated = 0
        self.decode_steps = 0
        self.spec_commits = 0
        self.spec_verifies = 0

    # -- introspection -----------------------------------------------------

    def _wire_stats(self, program, ins, tokens_per_step: float):
        """lower+compile ``program`` on its input specs and parse the ICI
        collectives; (CollectiveStats, total wire bytes per token across
        the mesh at ``tokens_per_step`` tokens committed per step)."""
        from ..launch import roofline as RL
        lowered = program.lower(
            self.params, self.cache.buffers, ins["token"], ins["pos"],
            ins["temp"], ins["key"])
        stats = RL.parse_collectives(lowered.compile().as_text())
        ndev = self.plan.dp_size * self.plan.tp_size
        per_tok = stats.wire_bytes * ndev / max(tokens_per_step, 1e-9)
        return stats, per_tok

    def decode_wire_stats(self):
        """Parse the compiled batched decode step's collectives.

        Returns (CollectiveStats, wire_bytes_per_token): per-device ICI
        bytes of ONE decode step, scaled to total bytes per generated
        token across the mesh.
        """
        ins, _ = serve_decode_input_specs(self.plan)
        return self._wire_stats(self._decode, ins, self.ecfg.num_slots)

    def verify_wire_stats(self, accepted_len: float = 1.0):
        """Parse the compiled k-token verify step's collectives.

        Returns (CollectiveStats, wire_bytes_per_token): per-device ICI
        bytes of ONE verify step, scaled to total bytes per *committed*
        token across the mesh at the given mean accepted length.  The
        verify step moves ~(spec_k+1)x the decode step's D-space
        activation bytes through the same coded boundaries — the traffic
        multiplier the spike wire absorbs; dividing by ``accepted_len``
        shows what the wire actually pays per token kept.
        """
        if self._verify is None:
            raise EngineConfigError("verify_wire_stats: spec_k == 0")
        ins, _ = serve_verify_input_specs(self.plan_ver, self.spec_k)
        return self._wire_stats(self._verify, ins,
                                self.ecfg.num_slots * accepted_len)
