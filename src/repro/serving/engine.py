"""Continuous-batching serving engine over the spike-coded decode path.

One ``ServingEngine`` owns a fixed pool of request slots (the decode
batch), a block-table ``PagedKVCache`` (shared KV page pool; slot-major
recurrent state), and up to four compiled programs:

  prefill : B=1, fixed-length right-padded prompt -> slot-shaped cache
            + the first sampled token (logits taken at the true last
            prompt position via ``last_pos``)
  insert  : splice the prefilled cache into a free slot (donated)
  decode  : ONE step for ALL slots at once — per-slot positions,
            per-slot temperatures, fused distributed sampling — with the
            cache donated so serving is allocation-free at steady state
  verify  : (``spec_k > 0``) the speculative sibling of decode — scores
            K1 = spec_k+1 positions per slot in one batched forward
            (last committed token + spec_k draft tokens from the
            deterministic prompt-lookup drafter), writes KV for all of
            them, and returns K1 sampled tokens per slot.  The scheduler
            keeps the longest draft prefix matching the verify output
            plus the first correction token, then rolls the rejected
            tail's cache occupancy back (``PagedKVCache.rollback``).
            Greedy spec decoding is token-identical to ``spec_k=0``
            (asserted by tests/dist_scenarios.py ``serving_spec_parity``);
            the k-fold decode-boundary traffic of the verify step rides
            the same coded collectives, which is exactly the workload
            the spike wire makes cheap.  Families with recurrent state
            fall back to ``spec_k=0`` — their state cannot roll back.

Scheduling is classic continuous batching: every ``step()`` first admits
queued requests into free slots (prefill-then-decode interleaving), then
runs a single batched decode step; finished requests (max tokens, EOS,
or context full) retire immediately and their slot AND its KV pages
return to the free pool for the next admit.  Admission maps only
``ceil(prompt_len / page_size)`` pages; each decode/verify step first
``ensure``s pages covering the positions it will write (alloc-on-
extend), raising typed ``PagePoolExhausted`` when the pool — not the
slot count — is the binding limit.  ``EngineConfig.num_pages`` sizes
the pool independently of ``num_slots * max_seq``; the default
reproduces the old dense reservation, so shrinking it is how the same
HBM holds more concurrent slots.

Every decode-path activation collective carries the spike/int8 wire
(``repro.core.boundary.coded_psum`` / ``wire_roundtrip``); the only fp
collectives left on the step are head-space layout exchanges (q/kv head
gathers) and the flash-decode LSE combine, which carry O(heads) metadata
rather than D-space activations.

All per-slot computation is batch-independent — no reduction mixes
slots, int8 scales are per-token — so under greedy decoding a slot's
token stream is bit-identical whether it shares the batch with 0 or
``num_slots-1`` neighbours (asserted by tests/dist_scenarios.py
``serving_parity``).  Stochastic sampling is per-slot independent in
distribution, but draws its Gumbel noise from the slot row and the
engine's step counter, so sampled streams are reproducible only for a
fixed schedule, not across different batch compositions.

Correctness note on padded prefill: right-padding is exact for
attention-family models (pad KV beyond ``last_pos`` is masked by the
per-slot position and overwritten as decode advances).  Families with
recurrent state (ssm/rnn/hybrid) fold pad tokens into the prefill-final
state, so their prompts must arrive at exactly ``prefill_len`` tokens;
the engine enforces this.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ShapeCell
from ..launch.serve import strip_dp_specs
from ..launch.specs import (cache_specs, default_num_pages, make_context,
                            make_plan, serve_decode_input_specs,
                            serve_verify_input_specs, verify_shape_cell)
from ..launch.train import shard_params_specs
from ..models import model as M
from . import sampling
from .draft import NGramDrafter
from .errors import (CacheOverflowError, EngineConfigError,
                     PagePoolExhausted, SchedulerStall, SlotsExhausted)
from .kv_cache import PagedKVCache
from .sampling import SamplingConfig

__all__ = ["CacheOverflowError", "EngineConfig", "EngineConfigError",
           "PagePoolExhausted", "Request", "SchedulerStall",
           "ServingEngine", "SlotsExhausted", "WARMUP_RID",
           "make_engine_decode_step", "make_engine_prefill_step",
           "make_engine_verify_step"]


#: Reserved request id for ``warmup``'s throwaway request.  A fresh
#: ``object()`` compares equal only to itself, so no user-supplied rid
#: (int, str, uuid, ...) can ever collide with it in a results dict.
WARMUP_RID = object()


@dataclasses.dataclass
class Request:
    """One generation request."""

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 4
    max_seq: int = 128
    prefill_len: int = 0           # 0 -> max_seq
    page_size: int = 64
    num_pages: int = 0             # KV pool size (0 -> dense-equivalent:
    #                                every slot can map pages_per_slot)
    top_k: int = 0
    top_p: float = 0.0
    eos_id: Optional[int] = None
    replicate_weights: bool = False
    seed: int = 0
    spec_k: int = 0                # draft tokens per verify step (0: off)


@dataclasses.dataclass
class _Slot:
    req: Request
    out: list
    drafter: Optional[NGramDrafter] = None


def make_engine_prefill_step(cfg, plan, mesh, scfg: SamplingConfig,
                             replicate_weights=False):
    """prefill(params, tokens[1,S], last_pos[1], temp[1], key) ->
    (first_token [1], cache)."""
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "prefill")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, cspecs = cache_specs(plan)

    def step(params, tokens, last_pos, temp, key):
        logits, caches = M.forward_prefill(params, {"tokens": tokens}, ctx,
                                           last_pos=last_pos)
        tok = sampling.sample(logits, key, temp, tp=ctx.tp,
                              tp_size=ctx.tp_size, cfg=scfg)
        return tok, caches

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P(None, plan.tp), P(None), P(None), P()),
        out_specs=(P(None), cspecs), check_vma=False)
    return jax.jit(fn)


def make_engine_decode_step(cfg, plan, mesh, scfg: SamplingConfig,
                            page_size, num_pages,
                            replicate_weights=False):
    """decode(params, cache, token[B], pos[B], bt[B,PPS], temp[B], key)
    -> (next_token [B], cache) — cache donated.

    ``cache`` is the shared KV page pool (+ slot-major state leaves);
    ``bt`` the per-slot block table the attention gathers K/V through.
    """
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, ispecs = serve_decode_input_specs(plan, page_size, num_pages)

    def step(params, cache, token, pos, bt, temp, key):
        logits, cache = M.forward_decode(params, cache, token, pos, ctx,
                                         aux_extra={"block_table": bt})
        tok = sampling.sample(logits, key, temp, tp=ctx.tp,
                              tp_size=ctx.tp_size, cfg=scfg)
        return tok, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["bt"], ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


def make_engine_verify_step(cfg, plan, mesh, scfg: SamplingConfig, spec_k,
                            page_size, num_pages,
                            replicate_weights=False):
    """verify(params, cache, tokens[B,K1], pos[B], bt[B,PPS], temp[B],
    key) -> (tokens_out [B,K1], cache) — cache donated.

    One batched forward over all K1 = spec_k+1 speculative positions of
    every slot; column j of ``tokens_out`` is the model's (greedy or
    sampled) next token after committing ``tokens[:, :j+1]``.  Reads and
    writes the same page pool + block table as the decode step.
    """
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, ispecs = serve_verify_input_specs(plan, spec_k, page_size, num_pages)

    def step(params, cache, tokens, pos, bt, temp, key):
        logits, cache = M.forward_verify(params, cache, tokens, pos, ctx,
                                         aux_extra={"block_table": bt})
        tok = sampling.sample_verify(logits, key, temp, tp=ctx.tp,
                                     tp_size=ctx.tp_size, cfg=scfg)
        return tok, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["bt"], ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


_RECURRENT_CACHE_KEYS = ("ssm_state", "rnn_state", "rwkv_state")


class ServingEngine:
    """Batched continuous-batching decode over a slot pool."""

    def __init__(self, cfg, mesh, params, ecfg: EngineConfig):
        if cfg.is_encdec:
            raise EngineConfigError("encoder-decoder serving: follow-on")
        self.cfg, self.mesh, self.params, self.ecfg = cfg, mesh, params, ecfg
        prefill_len = ecfg.prefill_len or ecfg.max_seq
        cell_dec = ShapeCell("serve_decode", ecfg.max_seq, ecfg.num_slots,
                             "decode")
        self.plan = make_plan(cfg, cell_dec, mesh)
        if not self.plan.batch_sharded:
            raise EngineConfigError(
                f"num_slots={ecfg.num_slots} must divide over the data axes "
                f"(dp_size={self.plan.dp_size})")
        if ecfg.max_seq % self.plan.tp_size != 0:
            raise EngineConfigError(
                f"max_seq={ecfg.max_seq} must be divisible by "
                f"tp_size={self.plan.tp_size}")
        if prefill_len % self.plan.tp_size != 0:
            raise EngineConfigError(
                f"prefill_len={prefill_len} must be divisible by "
                f"tp_size={self.plan.tp_size}")
        if ecfg.spec_k < 0:
            raise EngineConfigError(f"spec_k={ecfg.spec_k} must be >= 0")
        if ecfg.page_size < 1:
            raise EngineConfigError(f"page_size={ecfg.page_size} must be "
                                    ">= 1")
        shards = self.plan.dp_size * self.plan.tp_size
        self.num_pages = (ecfg.num_pages
                          or default_num_pages(self.plan, ecfg.page_size))
        if self.num_pages % shards != 0:
            raise EngineConfigError(
                f"num_pages={self.num_pages} must divide over the "
                f"dp x tp devices ({shards}) so the page pool shards "
                "evenly")
        cell_pre = ShapeCell("serve_admit", prefill_len, 1, "prefill")
        self.plan_pre = make_plan(cfg, cell_pre, mesh)
        self.prefill_len = prefill_len
        self._has_state = any(
            k in _RECURRENT_CACHE_KEYS
            for pos in cache_specs(self.plan)[0].values() for k in pos)
        # recurrent state folds every token in and cannot roll back a
        # rejected draft: those families serve vanilla (spec_k=0)
        self.spec_k = 0 if self._has_state else ecfg.spec_k

        scfg = SamplingConfig(top_k=ecfg.top_k, top_p=ecfg.top_p)
        self._prefill = make_engine_prefill_step(
            cfg, self.plan_pre, mesh, scfg, ecfg.replicate_weights)
        self._decode = make_engine_decode_step(
            cfg, self.plan, mesh, scfg, ecfg.page_size, self.num_pages,
            ecfg.replicate_weights)
        self._verify = None
        if self.spec_k > 0:
            self.plan_ver = make_plan(
                cfg, verify_shape_cell(ecfg.max_seq, ecfg.num_slots,
                                       self.spec_k), mesh)
            self._verify = make_engine_verify_step(
                cfg, self.plan_ver, mesh, scfg, self.spec_k,
                ecfg.page_size, self.num_pages, ecfg.replicate_weights)
        self.cache = PagedKVCache(self.plan, self.plan_pre, mesh,
                                  ecfg.page_size, self.num_pages)

        n = ecfg.num_slots
        self._tokens = np.zeros(n, np.int32)
        self._pos = np.zeros(n, np.int32)
        self._temp = np.zeros(n, np.float32)
        self._slots: list[Optional[_Slot]] = [None] * n
        self._queue: deque[Request] = deque()
        self._retired: list = []       # finished (request, tokens) pairs
        #                                awaiting pickup by step()
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._tick = 0
        self.tokens_generated = 0
        self.decode_steps = 0
        self.spec_commits = 0      # tokens committed by verify steps
        self.spec_verifies = 0     # (slot, verify-step) participations

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request):
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admit always "
                             "samples one token from the prefill logits)")
        P_len = len(req.prompt)
        if not 0 < P_len <= self.prefill_len:
            raise ValueError(
                f"prompt len {P_len} not in (0, {self.prefill_len}]")
        if self._has_state and P_len != self.prefill_len:
            raise ValueError(
                "recurrent-state families need prompt_len == prefill_len "
                f"({self.prefill_len}); right-padding would corrupt the "
                "prefill-final state")
        alloc = self.cache.allocator
        if alloc.pages_needed(P_len) > alloc.pages_per_group:
            raise ValueError(
                f"prompt needs {alloc.pages_needed(P_len)} KV pages but a "
                f"pool group only holds {alloc.pages_per_group} "
                f"(num_pages={self.num_pages}): the request could never "
                "be admitted")
        self._queue.append(req)

    def _next_key(self):
        self._tick += 1
        return jax.random.fold_in(self._key, self._tick)

    def _admit(self, req: Request):
        P_len = len(req.prompt)
        toks = np.zeros((1, self.prefill_len), np.int32)
        toks[0, :P_len] = np.asarray(req.prompt, np.int32)
        first, pre_cache = self._prefill(
            self.params, toks, np.array([P_len - 1], np.int32),
            np.array([req.temperature], np.float32), self._next_key())
        # admit maps ceil(P_len/page_size) pages — O(prompt), not
        # O(max_seq); each decode step maps the next page on demand
        slot = self.cache.admit(pre_cache, P_len)
        first = int(np.asarray(first)[0])
        drafter = None
        if self.spec_k > 0:
            drafter = NGramDrafter(list(req.prompt) + [first])
        self._slots[slot] = _Slot(req, [first], drafter)
        self._tokens[slot] = first
        self._pos[slot] = P_len
        self._temp[slot] = req.temperature
        self.tokens_generated += 1
        self._maybe_retire(slot, first)

    def _maybe_retire(self, slot: int, tok: int):
        st = self._slots[slot]
        done = (len(st.out) >= st.req.max_new_tokens
                or (self.ecfg.eos_id is not None and tok == self.ecfg.eos_id)
                or self._pos[slot] >= self.ecfg.max_seq)
        if done:
            # evict zeroes the slot's block-table row (-1), so the stale
            # pos/token the retired row still carries into the next
            # batched step can only produce dropped writes — a recycled
            # page can never be corrupted by its previous owner
            self.cache.evict(slot)
            self._slots[slot] = None
            self._retired.append((st.req, st.out))

    # -- scheduling --------------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def idle(self) -> bool:
        return not self._queue and self.num_active == 0

    def step(self) -> list:
        """Admit what fits, then one batched decode (or k-token verify)
        step.  Returns the requests finished this step as
        (request, tokens) pairs.

        Admission is gated on BOTH a free slot and free pool pages for
        the prompt (``can_admit``); a request that doesn't fit stays
        queued.  Before the device step, every active slot maps pages
        covering the positions the step will write (alloc-on-extend) —
        if a live slot cannot grow because its pool group is empty,
        ``PagePoolExhausted`` propagates: the pool, not the slot count,
        is the binding limit, and the operator sized ``num_pages`` below
        the workload's concurrent-context demand.
        """
        while self._queue and self.cache.allocator.can_admit(
                len(self._queue[0].prompt)):
            self._admit(self._queue.popleft())
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return self._drain_retired()
        if self.spec_k > 0:
            self._spec_step(active)
            return self._drain_retired()
        for i in active:
            # the step writes KV at position pos: map its page first
            self.cache.ensure(i, int(self._pos[i]) + 1)
        nxt, self.cache.buffers = self._decode(
            self.params, self.cache.buffers, self._tokens, self._pos,
            jnp.asarray(self.cache.block_table), self._temp,
            self._next_key())
        nxt = np.asarray(nxt)
        self.decode_steps += 1
        for i in active:
            tok = int(nxt[i])
            self._slots[i].out.append(tok)
            self._tokens[i] = tok
            self._pos[i] += 1
            self.tokens_generated += 1
            self._maybe_retire(i, tok)
        return self._drain_retired()

    def _drain_retired(self) -> list:
        """Hand the retirements accumulated so far to the caller.

        Retired (request, tokens) pairs buffer on the engine, not in a
        ``step()``-local, so a typed mid-step failure (e.g.
        ``PagePoolExhausted`` from an ``ensure``) cannot discard results
        of requests that already finished earlier in the same step —
        they surface from the next successful ``step()``.
        """
        out, self._retired = self._retired, []
        return out

    def _spec_step(self, active):
        """One speculative step: draft k per slot, verify all k+1
        positions in one batched forward, commit the longest accepted
        prefix plus the model's correction token, roll back the rest.

        Under greedy sampling the committed stream is token-identical to
        ``spec_k=0``: drafts only ever get accepted when they equal the
        argmax the vanilla step would have produced, and the first
        correction token is that argmax itself.
        """
        k = self.spec_k
        n = self.ecfg.num_slots
        drafts = np.zeros((n, k), np.int32)
        for i in active:
            drafts[i] = self._slots[i].drafter.propose(k)
            # the verify step writes KV at pos..pos+k (clipped at the
            # context end): map those pages before launching; the
            # rejected tail's pages roll back once acceptance is known
            self.cache.ensure(i, min(int(self._pos[i]) + k + 1,
                                     self.ecfg.max_seq))
        tok_in = np.concatenate([self._tokens[:, None], drafts], axis=1)
        out, self.cache.buffers = self._verify(
            self.params, self.cache.buffers, tok_in, self._pos,
            jnp.asarray(self.cache.block_table), self._temp,
            self._next_key())
        out = np.asarray(out)                                  # [n, k+1]
        self.decode_steps += 1
        for i in active:
            st = self._slots[i]
            a = 0
            while a < k and drafts[i, a] == out[i, a]:
                a += 1
            committed = 0
            for j in range(a + 1):                 # accepted drafts + fixup
                tok = int(out[i, j])
                st.out.append(tok)
                st.drafter.extend([tok])
                self._tokens[i] = tok
                self._pos[i] += 1
                self.tokens_generated += 1
                committed += 1
                if (len(st.out) >= st.req.max_new_tokens
                        or (self.ecfg.eos_id is not None
                            and tok == self.ecfg.eos_id)
                        or self._pos[i] >= self.ecfg.max_seq):
                    break
            self.cache.rollback(i, int(self._pos[i]))
            self.spec_commits += committed
            self.spec_verifies += 1
            self._maybe_retire(i, int(self._tokens[i]))

    @property
    def mean_accepted_len(self) -> float:
        """Mean tokens committed per (slot, verify-step) — >1.0 means the
        drafter is paying for itself."""
        return self.spec_commits / max(self.spec_verifies, 1)

    def run(self, requests: Sequence[Request], max_steps: int = 100000):
        """Serve ``requests`` to completion; {rid: generated tokens}."""
        for r in requests:
            self.submit(r)
        results = {}
        for _ in range(max_steps):
            for req, out in self.step():
                results[req.rid] = out
            if self.idle:
                break
        if not self.idle:
            raise SchedulerStall(
                f"run: {self.num_active} slots still active and "
                f"{len(self._queue)} requests queued after "
                f"{max_steps} steps")
        return results

    def warmup(self, prompt: Sequence[int]):
        """Compile the prefill/insert/decode/verify programs off the
        clock by serving one throwaway request, then zero the throughput
        stats.  The throwaway uses the reserved ``WARMUP_RID`` sentinel,
        which no user-supplied rid can equal."""
        self.run([Request(rid=WARMUP_RID, prompt=prompt, max_new_tokens=2)])
        self.reset_stats()

    def reset_stats(self):
        self.tokens_generated = 0
        self.decode_steps = 0
        self.spec_commits = 0
        self.spec_verifies = 0

    # -- introspection -----------------------------------------------------

    def _wire_stats(self, program, ins, tokens_per_step: float):
        """lower+compile ``program`` on its input specs and parse the ICI
        collectives; (CollectiveStats, total wire bytes per token across
        the mesh at ``tokens_per_step`` tokens committed per step)."""
        from ..launch import roofline as RL
        lowered = program.lower(
            self.params, self.cache.buffers, ins["token"], ins["pos"],
            ins["bt"], ins["temp"], ins["key"])
        stats = RL.parse_collectives(lowered.compile().as_text())
        ndev = self.plan.dp_size * self.plan.tp_size
        per_tok = stats.wire_bytes * ndev / max(tokens_per_step, 1e-9)
        return stats, per_tok

    def decode_wire_stats(self):
        """Parse the compiled batched decode step's collectives.

        Returns (CollectiveStats, wire_bytes_per_token): per-device ICI
        bytes of ONE decode step, scaled to total bytes per generated
        token across the mesh.
        """
        ins, _ = serve_decode_input_specs(self.plan, self.ecfg.page_size,
                                          self.num_pages)
        return self._wire_stats(self._decode, ins, self.ecfg.num_slots)

    def verify_wire_stats(self, accepted_len: float = 1.0):
        """Parse the compiled k-token verify step's collectives.

        Returns (CollectiveStats, wire_bytes_per_token): per-device ICI
        bytes of ONE verify step, scaled to total bytes per *committed*
        token across the mesh at the given mean accepted length.  The
        verify step moves ~(spec_k+1)x the decode step's D-space
        activation bytes through the same coded boundaries — the traffic
        multiplier the spike wire absorbs; dividing by ``accepted_len``
        shows what the wire actually pays per token kept.
        """
        if self._verify is None:
            raise EngineConfigError("verify_wire_stats: spec_k == 0")
        ins, _ = serve_verify_input_specs(self.plan_ver, self.spec_k,
                                          self.ecfg.page_size,
                                          self.num_pages)
        return self._wire_stats(self._verify, ins,
                                self.ecfg.num_slots * accepted_len)

    def pool_stats(self) -> dict:
        """KV pool occupancy + bytes, next to the dense baseline.

        ``kv_bytes_dense`` is what the pre-paging layout reserved
        (every slot charged ``pages_per_slot`` pages up front) — the
        ``kv_bytes_pool``/``kv_bytes_dense`` ratio is the HBM the block
        table frees for more slots at equal hardware.
        """
        alloc = self.cache.allocator
        return {
            "page_size": alloc.page_size,
            "num_pages": alloc.num_pages,
            "pages_in_use": alloc.pages_in_use,
            "peak_pages_in_use": self.cache.peak_pages_in_use,
            "kv_bytes_mapped": self.cache.kv_bytes_mapped(),
            "kv_bytes_pool": self.cache.kv_bytes_pool(),
            "kv_bytes_dense": self.cache.kv_bytes_dense_reservation(),
        }
