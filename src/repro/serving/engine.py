"""Continuous-batching serving engine over the spike-coded decode path.

One ``ServingEngine`` owns a fixed pool of request slots (the decode
batch), a block-table ``PagedKVCache`` (shared KV page pool; slot-major
recurrent state), and up to four compiled programs:

  prefill : B=1, fixed-length right-padded prompt -> slot-shaped cache
            + the first sampled token (logits taken at the true last
            prompt position via ``last_pos``)
  insert  : splice the prefilled cache into a free slot (donated)
  decode  : ONE step for ALL slots at once — per-slot positions,
            per-slot temperatures, fused distributed sampling — with the
            cache donated so serving is allocation-free at steady state
  verify  : (``spec_k > 0``) the speculative sibling of decode — scores
            K1 = spec_k+1 positions per slot in one batched forward
            (last committed token + spec_k draft tokens from the
            deterministic prompt-lookup drafter), writes KV for all of
            them, and returns K1 sampled tokens per slot.  The scheduler
            keeps the longest draft prefix matching the verify output
            plus the first correction token, then rolls the rejected
            tail's cache occupancy back (``PagedKVCache.rollback``).
            Greedy spec decoding is token-identical to ``spec_k=0``
            (asserted by tests/dist_scenarios.py ``serving_spec_parity``);
            the k-fold decode-boundary traffic of the verify step rides
            the same coded collectives, which is exactly the workload
            the spike wire makes cheap.  Families with recurrent state
            fall back to ``spec_k=0`` — their state cannot roll back.

Scheduling is classic continuous batching: every ``step()`` first admits
queued requests into free slots (prefill-then-decode interleaving), then
runs a single batched decode step; finished requests (max tokens, EOS,
or context full) retire immediately and their slot AND its KV pages
return to the free pool for the next admit.

Async decode streams (``EngineConfig.async_depth``): the engine is a
dispatch/commit pipeline.  ``dispatch()`` admits what fits and LAUNCHES
one batched device step without waiting for its tokens; ``commit()``
joins the oldest in-flight step (the only host sync on the hot path)
and applies its bookkeeping.  ``async_depth=0`` (default) commits every
dispatch immediately — the classic synchronous loop.  ``async_depth=1``
dispatches step t+1 before fetching step t's tokens: the token feed for
t+1 is step t's sampled-token DEVICE array chained straight back in
(XLA pipelines the two steps; the host never round-trips the values),
positions advance deterministically by one, and each dispatch stages
fresh double-buffered token/pos/block-table device arrays so host-side
scheduling for t+1 never races step t's transfers.  Retirement the host
can predict (token count, context end) is applied at dispatch so dead
slots stop being scheduled instantly; EOS is only discoverable at
commit, one step late under overlap — the already-dispatched zombie
step's token for that slot is discarded (slot identity, not index, ties
outputs to requests) and the pages it touched return through the
cache's deferred-free epoch, never to a concurrently-dispatched
snapshot.  Prefill admits are issued eagerly between decode dispatches
(the prefill overlaps the in-flight step; the new slot joins the batch
at the next dispatch), and admission itself never syncs: the prefill's
sampled first token stays a DEVICE array (``_Slot.pending_first``)
that the next decode feed patches straight in; its value folds into
host bookkeeping at the slot's first commit — by which point the sync
is free — or at a verify dispatch (drafting needs host tokens).

Graceful degradation: when a live slot cannot map its next page
(``PagePoolExhausted``) and ``EngineConfig.preempt`` is on, the engine
first drains the pipeline (deferred-free limbo pages rejoin the pool at
commit) and then evicts + re-queues the YOUNGEST slot of the starving
pool group, restarting it from scratch on re-admit — under greedy
sampling the restarted stream is bit-identical to an uninterrupted run,
so preemption shows up only in latency, never in tokens
(tests/test_faults.py).  ``preempt_slot`` exposes the same move to
fault injectors (``repro.serving.slo.FaultInjector``), and
``suspend``/``resume`` drain + snapshot + re-admit the whole engine for
simulated host preemption or replica loss.  Observer objects appended
to ``engine.observers`` receive ``on_submit`` / ``on_admit`` /
``on_first_token`` / ``on_finish`` / ``on_preempt`` / ``on_suspend``
lifecycle callbacks (see ``repro.serving.slo.SLOMonitor``).  Under greedy sampling the async schedule is
token-identical to the sync loop — per-slot streams are batch-
independent and the chained device tokens are the very same values the
host would have fed back — asserted by ``tests/test_engine_fuzz.py``
and the ``serving_parity``/``serving_spec_parity`` scenarios.  With
``spec_k > 0`` and the default ``drafter="ngram"`` the host must see
step t's accepted tokens before it can draft step t+1, so a verify
dispatch first joins the pipeline; what still overlaps is admission
prefill against the in-flight verify step.  ``drafter="heads"`` removes
that join: trained draft heads (``models.draft_heads``) ride the verify
step itself, so each step emits — on device — both its sampled tokens
AND the next step's complete feed (accepted token + head-argmax drafts)
plus chained positions, and the host dispatches verify t+1 against
those device arrays without ever syncing step t.  ``spec_k > 0`` then
composes with ``async_depth > 0`` exactly like the plain decode path
(acceptance bookkeeping is recomputed at commit from the synced feed
snapshot; truncation always retires the slot, so any column whose
device-side position ran ahead of the host is a zombie discarded by
slot identity, and page reclaim defers to the last in-flight commit of
the chain).  Heads drafting needs a trained ``"draft_heads"`` subtree
in the params tree (``examples/train_hnn_lm.py --draft-heads``);
non-heads programs strip it so their compiled signatures stay
trunk-only.

Admission maps only
``ceil(prompt_len / page_size)`` pages; each decode/verify step first
``ensure``s pages covering the positions it will write (alloc-on-
extend), raising typed ``PagePoolExhausted`` when the pool — not the
slot count — is the binding limit.  ``EngineConfig.num_pages`` sizes
the pool independently of ``num_slots * max_seq``; the default
reproduces the old dense reservation, so shrinking it is how the same
HBM holds more concurrent slots.

Every decode-path activation collective carries the spike/int8 wire:
D-space boundaries through ``repro.core.boundary.coded_psum`` /
``wire_roundtrip``, and the head-space exchanges — q/kv head gathers
(``coded_head_all_gather``) and the flash-decode partial combine
(``coded_combine_partials``, fed by the fused kernel's int8 epilogue) —
through per-token absmax int8.  The only uncoded decode-step traffic
left is the O(heads) LSE scalars riding the combine.

All per-slot computation is batch-independent — no reduction mixes
slots, int8 scales are per-token — so under greedy decoding a slot's
token stream is bit-identical whether it shares the batch with 0 or
``num_slots-1`` neighbours (asserted by tests/dist_scenarios.py
``serving_parity``).  Stochastic sampling is per-slot independent in
distribution, but draws its Gumbel noise from the slot row and the
engine's step counter, so sampled streams are reproducible only for a
fixed schedule, not across different batch compositions.

Correctness note on padded prefill: right-padding is exact for
attention-family models (pad KV beyond ``last_pos`` is masked by the
per-slot position and overwritten as decode advances).  Families with
recurrent state (ssm/rnn/hybrid) fold pad tokens into the prefill-final
state, so their prompts must arrive at exactly ``prefill_len`` tokens;
the engine enforces this.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ShapeCell
from ..launch.serve import strip_dp_specs
from ..launch.specs import (cache_specs, default_num_pages, make_context,
                            make_plan, serve_decode_input_specs,
                            serve_feed_specs, serve_heads_feed_specs,
                            serve_verify_input_specs, verify_shape_cell)
from ..launch.train import shard_params_specs
from ..models import common as MC
from ..models import draft_heads as DH
from ..models import model as M
from ..models import params as PR
from . import sampling
from .draft import NGramDrafter
from .errors import (CacheOverflowError, EngineConfigError,
                     PagePoolExhausted, SchedulerStall, SlotsExhausted)
from .kv_cache import PagedKVCache
from .sampling import SamplingConfig

__all__ = ["CacheOverflowError", "EngineConfig", "EngineConfigError",
           "PagePoolExhausted", "Request", "SchedulerStall",
           "ServingEngine", "SlotsExhausted", "WARMUP_RID",
           "make_engine_decode_step", "make_engine_heads_verify_step",
           "make_engine_prefill_step", "make_engine_verify_step"]


#: Reserved request id for ``warmup``'s throwaway request.  A fresh
#: ``object()`` compares equal only to itself, so no user-supplied rid
#: (int, str, uuid, ...) can ever collide with it in a results dict.
WARMUP_RID = object()


@dataclasses.dataclass
class Request:
    """One generation request."""

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 4
    max_seq: int = 128
    prefill_len: int = 0           # 0 -> max_seq
    page_size: int = 64
    num_pages: int = 0             # KV pool size (0 -> dense-equivalent:
    #                                every slot can map pages_per_slot)
    top_k: int = 0
    top_p: float = 0.0
    eos_id: Optional[int] = None
    replicate_weights: bool = False
    seed: int = 0
    spec_k: int = 0                # draft tokens per verify step (0: off)
    drafter: str = "ngram"         # speculative draft source: "ngram"
    #                                (deterministic host-side prompt
    #                                lookup — needs committed tokens, so
    #                                every verify dispatch joins the
    #                                pipeline first) or "heads" (trained
    #                                draft heads evaluated ON DEVICE
    #                                inside the verify step — the feed
    #                                for step t+1 chains from step t
    #                                without a host sync, so spec_k
    #                                composes with async_depth; requires
    #                                a "draft_heads" params subtree)
    async_depth: int = 0           # decode steps the host may dispatch
    #                                ahead of the oldest un-synced step
    #                                (0: classic synchronous loop)
    preempt: bool = True           # on PagePoolExhausted mid-flight,
    #                                evict + re-queue the youngest slot
    #                                in the starving pool group instead
    #                                of failing the step (False: the
    #                                typed error propagates)
    attn_kernel: str = "fused"     # paged decode attention path:
    #                                "fused" walks the compacted per-shard
    #                                page lists in one Pallas kernel
    #                                (kernels/paged_decode.py, interpret
    #                                mode off-TPU); "reference" scores the
    #                                full block table per shard — the
    #                                oracle the fused path is fuzz-checked
    #                                against
    disagg: bool = False           # disaggregated prefill/decode roles:
    #                                dedicate the first prefill_groups dp
    #                                groups to admission prefills and
    #                                migrate each finished prefill's paged
    #                                KV (+ state rows) to a decode-role
    #                                group through one coded ppermute
    #                                (False: colocated, behavior-identical
    #                                to the pre-disagg engine)
    prefill_groups: int = 1        # dp groups dedicated to prefill when
    #                                disagg=True (the rest decode); must
    #                                satisfy 0 < prefill_groups < dp_size
    kv_wire: str = "fp"            # KV payload discipline at pool insert
    #                                and on the migration wire: "fp"
    #                                (exact, default) or "coded" (pow2-
    #                                absmax int8 roundtrip at insert +
    #                                int8 wire on migration — lossy once,
    #                                then idempotent, so disagg stays
    #                                token-identical to colocated)
    router: str = "load"           # disagg admission router picking the
    #                                migration target among decode
    #                                groups: "load" (fewest pages mapped
    #                                + in limbo) or "rr" (round-robin)


@dataclasses.dataclass
class _Slot:
    req: Request
    out: list
    drafter: Optional[NGramDrafter] = None
    #: uncommitted dispatched steps this slot participates in
    inflight: int = 0
    #: scheduled for future dispatches; False once the host knows (or
    #: can predict) the request is finished
    live: bool = True
    #: admission order (monotonic engine counter) — preemption picks
    #: victims youngest-first so the oldest request always progresses
    seq: int = 0
    #: the admit prefill's sampled first token, still a DEVICE [1] array
    #: (deferred first-token sync: the host never blocks on it at admit;
    #: the value folds into host bookkeeping at the slot's first commit,
    #: at verify dispatch, or when nothing else can run)
    pending_first: Optional[object] = None


@dataclasses.dataclass
class _Resume:
    """Queue entry for a suspended mid-generation request: re-admit with
    the committed tokens as part of the prompt (work-preserving) instead
    of restarting from scratch.

    The effective prefill prompt is ``req.prompt + prior``; the admitted
    slot's ``out`` is pre-seeded with ``prior`` so retirement limits,
    committed-position accounting and the final output all see the full
    request — under greedy sampling the re-prefilled continuation is
    token-identical to the uninterrupted run, so only latency, not
    output, records the suspension.  ``suspend`` only creates one when
    the combined length still fits the prefill path (and, for
    recurrent families, lands on a valid exact-length bucket);
    otherwise it falls back to the old restart-from-scratch entry.
    """

    req: Request
    prior: list                      # committed tokens at suspend time

    @property
    def rid(self):
        return self.req.rid


@dataclasses.dataclass
class _InFlight:
    """One dispatched, not-yet-committed batched device step."""

    kind: str                          # "decode" | "verify" | "verify_heads"
    #: (slot index, _Slot) pairs live at dispatch time — the OBJECT, not
    #: the index, ties the step's outputs to requests, so a slot retired
    #: (or even re-admitted) between dispatch and commit simply drops
    #: its column instead of corrupting the new occupant
    entries: list
    out: object                        # device token future [n] or [n,K1]
    drafts: Optional[np.ndarray] = None   # [n, spec_k] (ngram verify only)
    #: heads verify only: the DEVICE feed/pos snapshot this step scored
    #: — synced at commit to recompute acceptance host-side (the drafts
    #: never visit the host before the step that scores them runs)
    feed_in: Optional[object] = None      # device [n, K1]
    pos_in: Optional[object] = None       # device [n]


def make_engine_prefill_step(cfg, plan, mesh, scfg: SamplingConfig,
                             replicate_weights=False):
    """prefill(params, tokens[1,S], last_pos[1], temp[1], key) ->
    (first_token [1], cache)."""
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "prefill")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, cspecs = cache_specs(plan)

    def step(params, tokens, last_pos, temp, key):
        logits, caches = M.forward_prefill(params, {"tokens": tokens}, ctx,
                                           last_pos=last_pos)
        tok = sampling.sample(logits, key, temp, tp=ctx.tp,
                              tp_size=ctx.tp_size, cfg=scfg)
        return tok, caches

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P(None, plan.tp), P(None), P(None), P()),
        out_specs=(P(None), cspecs), check_vma=False)
    return jax.jit(fn)


def make_engine_decode_step(cfg, plan, mesh, scfg: SamplingConfig,
                            page_size, num_pages,
                            replicate_weights=False,
                            attn_kernel="fused"):
    """decode(params, cache, token[B], pos[B], bt[B,PPS], clp[B,S,ppc],
    clo[B,S,ppc], temp[B], key) -> (next_token [B], cache) — cache
    donated.

    ``cache`` is the shared KV page pool (+ slot-major state leaves);
    ``bt`` the per-slot block table the attention writes K/V through;
    ``clp``/``clo`` the compacted per-shard page lists (local page rows
    / start positions) the fused attention kernel walks.  With
    ``attn_kernel="reference"`` the lists are staged but unused and
    attention gathers the full block table per shard.
    """
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, ispecs = serve_decode_input_specs(plan, page_size, num_pages)
    fused = attn_kernel == "fused"

    def step(params, cache, token, pos, bt, clp, clo, temp, key):
        aux = {"block_table": bt}
        if fused:
            aux["page_list"] = (clp, clo)
        logits, cache = M.forward_decode(params, cache, token, pos, ctx,
                                         aux_extra=aux)
        tok = sampling.sample(logits, key, temp, tp=ctx.tp,
                              tp_size=ctx.tp_size, cfg=scfg)
        return tok, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["bt"], ispecs["clp"], ispecs["clo"],
                  ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


def make_engine_verify_step(cfg, plan, mesh, scfg: SamplingConfig, spec_k,
                            page_size, num_pages,
                            replicate_weights=False,
                            attn_kernel="fused"):
    """verify(params, cache, tokens[B,K1], pos[B], bt[B,PPS], clp, clo,
    temp[B], key) -> (tokens_out [B,K1], cache) — cache donated.

    One batched forward over all K1 = spec_k+1 speculative positions of
    every slot; column j of ``tokens_out`` is the model's (greedy or
    sampled) next token after committing ``tokens[:, :j+1]``.  Reads and
    writes the same page pool + block table as the decode step, and
    takes the same compacted page lists for the fused attention path
    (the kernel covers K1 >= 1 with one code path).
    """
    _, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)
    _, ispecs = serve_verify_input_specs(plan, spec_k, page_size, num_pages)
    fused = attn_kernel == "fused"

    def step(params, cache, tokens, pos, bt, clp, clo, temp, key):
        aux = {"block_table": bt}
        if fused:
            aux["page_list"] = (clp, clo)
        logits, cache = M.forward_verify(params, cache, tokens, pos, ctx,
                                         aux_extra=aux)
        tok = sampling.sample_verify(logits, key, temp, tp=ctx.tp,
                                     tp_size=ctx.tp_size, cfg=scfg)
        return tok, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["bt"], ispecs["clp"], ispecs["clo"],
                  ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


def make_engine_heads_verify_step(cfg, plan, mesh, scfg: SamplingConfig,
                                  spec_k, page_size, num_pages, max_seq,
                                  replicate_weights=False,
                                  attn_kernel="fused"):
    """verify_heads(params, cache, tokens[B,K1], pos[B], bt, clp, clo,
    temp[B], key) -> (tokens_out [B,K1], feed_next [B,K1],
    pos_next [B], cache) — cache donated.

    The device-drafting sibling of ``make_engine_verify_step``: the same
    batched K1-position forward and sampler, but ``params`` carries a
    ``"draft_heads"`` subtree (replicated — see ``models.draft_heads``)
    and the step ALSO computes, entirely on device, everything the next
    verify dispatch needs:

      acc       longest prefix of the fed drafts ``tokens[:, 1:]``
                matching the sampled outputs ``tok[:, :-1]`` — the exact
                acceptance rule the host applies at commit
      corr      the correction/bonus token ``tok[:, acc]`` (the last
                token the commit will keep)
      feed_next ``[corr, head-argmax drafts]``: the draft heads read the
                post-roundtrip hidden at the accepted position (h is
                replicated across tp ranks there, so replicated heads
                draft identically per rank with zero new collectives),
                project through the tp-sharded LM head, and take the
                distributed argmax
      pos_next  ``min(pos + acc + 1, max_seq)`` — the committed position
                the host will reach for any slot it neither truncates
                nor retires (truncation always retires, making the
                slot's later in-flight columns zombies)

    Chaining (feed_next, pos_next) into the next dispatch is what
    deletes the ngram drafter's host join: greedy identity still holds
    structurally because garbage drafts merely fail acceptance.
    """
    _, pspecs, _ = shard_params_specs(cfg, plan)
    hspecs = PR.specs_tree(DH.draft_head_defs(cfg, 1), plan.dp, plan.tp)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        hspecs = strip_dp_specs(hspecs)
        ctx = ctx.with_(dp_size=1)
    pspecs = dict(pspecs)
    pspecs["draft_heads"] = hspecs
    _, ispecs = serve_verify_input_specs(plan, spec_k, page_size, num_pages)
    fused = attn_kernel == "fused"
    k = spec_k

    def step(params, cache, tokens, pos, bt, clp, clo, temp, key):
        aux = {"block_table": bt}
        if fused:
            aux["page_list"] = (clp, clo)
        logits, cache, h = M.forward_verify(params, cache, tokens, pos,
                                            ctx, aux_extra=aux,
                                            return_hidden=True)
        tok = sampling.sample_verify(logits, key, temp, tp=ctx.tp,
                                     tp_size=ctx.tp_size, cfg=scfg)
        match = (tokens[:, 1:] == tok[:, :-1]).astype(jnp.int32)
        acc = jnp.cumprod(match, axis=1).sum(axis=1)           # [B] 0..k
        corr = jnp.take_along_axis(tok, acc[:, None], axis=1)[:, 0]
        h_acc = jnp.take_along_axis(h, acc[:, None, None], axis=1)[:, 0]
        z = DH.head_hiddens(params["draft_heads"], h_acc)      # [B,H,D]
        head = M._head_w(params, ctx)                          # [D,V_loc]
        dlog = (z @ head.astype(z.dtype)).astype(jnp.float32)
        if cfg.final_softcap:
            dlog = MC.softcap(dlog, cfg.final_softcap)
        drafts = sampling.dist_argmax(dlog, ctx.tp, ctx.tp_size)  # [B,H]
        feed = jnp.concatenate([corr[:, None], drafts[:, :k]], axis=1)
        pos_next = jnp.minimum(pos + acc + 1, max_seq)
        return tok, feed, pos_next, cache

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"],
                  ispecs["bt"], ispecs["clp"], ispecs["clo"],
                  ispecs["temp"], ispecs["key"]),
        out_specs=(ispecs["token"], ispecs["token"], ispecs["pos"],
                   ispecs["cache"]), check_vma=False)
    return jax.jit(fn, donate_argnums=(1,))


_RECURRENT_CACHE_KEYS = ("ssm_state", "rnn_state", "rwkv_state")


class ServingEngine:
    """Batched continuous-batching decode over a slot pool."""

    def __init__(self, cfg, mesh, params, ecfg: EngineConfig):
        if cfg.is_encdec:
            raise EngineConfigError("encoder-decoder serving: follow-on")
        self.cfg, self.mesh, self.params, self.ecfg = cfg, mesh, params, ecfg
        prefill_len = ecfg.prefill_len or ecfg.max_seq
        cell_dec = ShapeCell("serve_decode", ecfg.max_seq, ecfg.num_slots,
                             "decode")
        self.plan = make_plan(cfg, cell_dec, mesh)
        if not self.plan.batch_sharded:
            raise EngineConfigError(
                f"num_slots={ecfg.num_slots} must divide over the data axes "
                f"(dp_size={self.plan.dp_size})")
        if ecfg.max_seq % self.plan.tp_size != 0:
            raise EngineConfigError(
                f"max_seq={ecfg.max_seq} must be divisible by "
                f"tp_size={self.plan.tp_size}")
        if prefill_len % self.plan.tp_size != 0:
            raise EngineConfigError(
                f"prefill_len={prefill_len} must be divisible by "
                f"tp_size={self.plan.tp_size}")
        if ecfg.spec_k < 0:
            raise EngineConfigError(f"spec_k={ecfg.spec_k} must be >= 0")
        if ecfg.async_depth < 0:
            raise EngineConfigError(
                f"async_depth={ecfg.async_depth} must be >= 0")
        if ecfg.page_size < 1:
            raise EngineConfigError(f"page_size={ecfg.page_size} must be "
                                    ">= 1")
        shards = self.plan.dp_size * self.plan.tp_size
        self.num_pages = (ecfg.num_pages
                          or default_num_pages(self.plan, ecfg.page_size))
        if self.num_pages % shards != 0:
            raise EngineConfigError(
                f"num_pages={self.num_pages} must divide over the "
                f"dp x tp devices ({shards}) so the page pool shards "
                "evenly")
        if ecfg.attn_kernel not in ("fused", "reference"):
            raise EngineConfigError(
                f"attn_kernel={ecfg.attn_kernel!r}: expected 'fused' or "
                "'reference'")
        if ecfg.drafter not in ("ngram", "heads"):
            raise EngineConfigError(
                f"drafter={ecfg.drafter!r}: expected 'ngram' or 'heads'")
        if ecfg.kv_wire not in ("fp", "coded"):
            raise EngineConfigError(
                f"kv_wire={ecfg.kv_wire!r}: expected 'fp' or 'coded'")
        if ecfg.router not in ("load", "rr"):
            raise EngineConfigError(
                f"router={ecfg.router!r}: expected 'load' or 'rr'")
        if ecfg.disagg:
            if len(self.plan.dp) != 1:
                raise EngineConfigError(
                    "disagg=True needs exactly one dp mesh axis (the "
                    f"migration ppermute axis); plan has {self.plan.dp}")
            if self.plan.dp_size < 2:
                raise EngineConfigError(
                    "disagg=True needs dp_size >= 2 (at least one "
                    "prefill-role and one decode-role group); "
                    f"dp_size={self.plan.dp_size}")
            if not 0 < ecfg.prefill_groups < self.plan.dp_size:
                raise EngineConfigError(
                    f"prefill_groups={ecfg.prefill_groups} must be in "
                    f"(0, dp_size={self.plan.dp_size}): both roles need "
                    "at least one dp group")
        cell_pre = ShapeCell("serve_admit", prefill_len, 1, "prefill")
        self.plan_pre = make_plan(cfg, cell_pre, mesh)
        self.prefill_len = prefill_len
        self._has_state = any(
            k in _RECURRENT_CACHE_KEYS
            for pos in cache_specs(self.plan)[0].values() for k in pos)
        # recurrent state folds every token in and cannot roll back a
        # rejected draft: those families serve vanilla (spec_k=0)
        self.spec_k = 0 if self._has_state else ecfg.spec_k
        self.drafter_kind = ecfg.drafter
        if ecfg.drafter == "heads":
            if ecfg.spec_k <= 0:
                raise EngineConfigError(
                    "drafter='heads' requires spec_k > 0 (the heads only "
                    "ever draft inside speculative verify steps)")
            if self.spec_k > 0:
                if not (isinstance(params, dict)
                        and "draft_heads" in params):
                    raise EngineConfigError(
                        "drafter='heads' needs trained draft-head params: "
                        "the params tree has no 'draft_heads' subtree — "
                        "train one (examples/train_hnn_lm.py "
                        "--draft-heads K) and restore its checkpoint")
                n_heads = int(params["draft_heads"]["w1"].shape[0])
                if n_heads < self.spec_k:
                    raise EngineConfigError(
                        f"drafter='heads': {n_heads} draft heads < "
                        f"spec_k={self.spec_k} (one head per draft "
                        "position)")
        #: the params tree WITHOUT the draft-heads subtree: every program
        #: except the heads verify step compiles against trunk-only
        #: shard_map in_specs, so an extra params key would be a pytree
        #: mismatch — strip it once here
        self._trunk = params
        if isinstance(params, dict) and "draft_heads" in params:
            self._trunk = {kk: v for kk, v in params.items()
                           if kk != "draft_heads"}

        scfg = SamplingConfig(top_k=ecfg.top_k, top_p=ecfg.top_p)
        self._scfg = scfg
        self._prefill = make_engine_prefill_step(
            cfg, self.plan_pre, mesh, scfg, ecfg.replicate_weights)
        #: exact-length prefill buckets for recurrent families: seq len
        #: -> (compiled prefill step, its plan) — lazy, the default
        #: full-length bucket is pre-registered
        self._prefill_buckets = {prefill_len: (self._prefill,
                                               self.plan_pre)}
        self._decode = make_engine_decode_step(
            cfg, self.plan, mesh, scfg, ecfg.page_size, self.num_pages,
            ecfg.replicate_weights, ecfg.attn_kernel)
        self._verify = None
        if self.spec_k > 0:
            self.plan_ver = make_plan(
                cfg, verify_shape_cell(ecfg.max_seq, ecfg.num_slots,
                                       self.spec_k), mesh)
            if self.drafter_kind == "heads":
                self._verify = make_engine_heads_verify_step(
                    cfg, self.plan_ver, mesh, scfg, self.spec_k,
                    ecfg.page_size, self.num_pages, ecfg.max_seq,
                    ecfg.replicate_weights, ecfg.attn_kernel)
            else:
                self._verify = make_engine_verify_step(
                    cfg, self.plan_ver, mesh, scfg, self.spec_k,
                    ecfg.page_size, self.num_pages,
                    ecfg.replicate_weights, ecfg.attn_kernel)
        self.cache = PagedKVCache(self.plan, self.plan_pre, mesh,
                                  ecfg.page_size, self.num_pages,
                                  kv_wire=ecfg.kv_wire)
        #: disaggregated roles: the first ``prefill_groups`` dp groups
        #: take admission prefills, the rest decode; colocated engines
        #: leave both None and admit anywhere
        self._prefill_group_ids = None
        self._decode_group_ids = None
        if ecfg.disagg:
            ng = self.cache.allocator.num_groups
            self._prefill_group_ids = tuple(range(ecfg.prefill_groups))
            self._decode_group_ids = tuple(range(ecfg.prefill_groups, ng))
        self._rr_next = 0              # round-robin router cursor

        n = ecfg.num_slots
        self._tokens = np.zeros(n, np.int32)
        self._pos = np.zeros(n, np.int32)
        self._temp = np.zeros(n, np.float32)
        self._slots: list[Optional[_Slot]] = [None] * n
        self._queue: deque[Request] = deque()
        self._retired: list = []       # finished (request, tokens) pairs
        #                                awaiting pickup by step()
        # -- dispatch/commit pipeline state --
        self.async_depth = ecfg.async_depth
        self._inflight: deque[_InFlight] = deque()
        if self.spec_k > 0 and self.drafter_kind == "heads":
            self._feed_specs = serve_heads_feed_specs(
                self.plan, ecfg.page_size, self.spec_k)
        else:
            self._feed_specs = serve_feed_specs(self.plan, ecfg.page_size,
                                                self.spec_k)
        #: last decode dispatch's sampled-token DEVICE array: the token
        #: feed of the next dispatch chains it back in without a host
        #: round-trip (None until the first decode dispatch)
        self._tok_dev = None
        #: slots whose next feed token must come from the host shadow
        #: (``self._tokens``) — slots whose deferred first token has
        #: been folded to the host since the last decode dispatch
        self._tok_dirty: set[int] = set()
        #: slot -> device [1] first-token array from the admit prefill:
        #: the next decode feed patches these straight from the device
        #: (the value never visits the host on the admission path)
        self._tok_pending: dict[int, object] = {}
        #: heads drafter: the last verify dispatch's chained
        #: (feed [B,K1], pos [B]) DEVICE arrays — the next dispatch's
        #: inputs, with dirty/pending slots patched in (None until the
        #: first heads verify dispatch)
        self._vfeed_dev = None
        self._vpos_dev = None
        self._admit_seq = 0
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._tick = 0
        self.tokens_generated = 0
        self.decode_steps = 0
        self.spec_commits = 0      # tokens committed by verify steps
        self.spec_verifies = 0     # (slot, verify-step) participations
        self.pipelined_dispatches = 0  # verify dispatches launched while
        #                                another step was still un-synced
        #                                — the host join the heads drafter
        #                                deletes; structurally 0 for
        #                                drafter="ngram" (tests assert
        #                                both directions)
        self.preemptions = 0       # evict + re-queue events (pool
        #                            pressure or injected faults)
        self.suspends = 0          # drain + snapshot + resume events
        self.migrations = 0        # prefill -> decode KV handoffs (disagg)
        self.migrated_wire_bytes = 0   # coded/fp bytes those handoffs put
        #                                on the dp boundary (shape-static
        #                                per migration)
        #: observability hooks: objects whose optional ``on_submit`` /
        #: ``on_admit`` / ``on_first_token`` / ``on_finish`` /
        #: ``on_preempt`` / ``on_suspend`` / ``on_migrate`` methods are
        #: called at the matching lifecycle points (see
        #: ``repro.serving.slo``); the per-tick ``on_step`` hook stays
        #: on ``run(on_step=...)``
        self.observers: list = []

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request):
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admit always "
                             "samples one token from the prefill logits)")
        P_len = len(req.prompt)
        if not 0 < P_len <= self.prefill_len:
            raise ValueError(
                f"prompt len {P_len} not in (0, {self.prefill_len}]")
        if self._has_state and P_len % self.plan.tp_size != 0:
            # right-padding would corrupt the prefill-final recurrent
            # state, so these families prefill through an EXACT-length
            # bucket instead — any multiple of tp_size (the sequence
            # sharding granularity) up to prefill_len is admissible
            raise ValueError(
                "recurrent-state families prefill exact-length buckets: "
                f"prompt len {P_len} must be a multiple of tp_size "
                f"({self.plan.tp_size})")
        alloc = self.cache.allocator
        if alloc.pages_needed(P_len) > alloc.pages_per_group:
            raise ValueError(
                f"prompt needs {alloc.pages_needed(P_len)} KV pages but a "
                f"pool group only holds {alloc.pages_per_group} "
                f"(num_pages={self.num_pages}): the request could never "
                "be admitted")
        self._queue.append(req)
        self._emit("on_submit", req.rid, P_len)

    def _emit(self, event: str, *args):
        for obs in self.observers:
            fn = getattr(obs, event, None)
            if fn is not None:
                fn(*args)

    def _next_key(self):
        self._tick += 1
        return jax.random.fold_in(self._key, self._tick)

    @staticmethod
    def _entry_parts(entry):
        """(request, prior committed tokens, effective prefill prompt)
        for a queue entry — ``Request`` or a suspend-time ``_Resume``."""
        if isinstance(entry, _Resume):
            return (entry.req, entry.prior,
                    list(entry.req.prompt) + list(entry.prior))
        return entry, [], list(entry.prompt)

    def _prefill_for(self, P_len: int):
        """(padded seq len, compiled prefill step, its plan) for a
        ``P_len``-token prompt.

        Attention families right-pad into the single full-length prefill
        (exact — padded positions are causally masked and never
        attended).  Recurrent families fold every position into the
        running state, so padding is NOT exact: they prefill through an
        exact-length bucket instead, compiled lazily per distinct prompt
        length (``submit`` guarantees tp_size-divisibility).
        """
        if not self._has_state:
            return self.prefill_len, self._prefill, self.plan_pre
        if P_len not in self._prefill_buckets:
            cell = ShapeCell("serve_admit", P_len, 1, "prefill")
            plan_b = make_plan(self.cfg, cell, self.mesh)
            prog = make_engine_prefill_step(
                self.cfg, plan_b, self.mesh, self._scfg,
                self.ecfg.replicate_weights)
            self._prefill_buckets[P_len] = (prog, plan_b)
        prog, plan_b = self._prefill_buckets[P_len]
        return P_len, prog, plan_b

    def _admit(self, entry):
        """Prefill a queue entry (``Request`` or ``_Resume``) into a free
        slot — with NO host sync.

        The prefill/insert launches are asynchronous, so under
        ``async_depth > 0`` they overlap whatever decode/verify step is
        currently in flight (XLA orders them behind it on the donated
        cache buffers).  The first sampled token stays a DEVICE array
        (``_Slot.pending_first``): the next decode dispatch patches it
        straight into the chained token feed, so admission never blocks
        the host on a fresh prefill.  The value folds into host
        bookkeeping (``out``, EOS check, drafter seed) at the slot's
        first commit — by which time the prefill has long executed and
        the sync is free — or earlier when the spec path needs host
        tokens to draft.
        """
        req, prior, prompt = self._entry_parts(entry)
        P_len = len(prompt)
        S_pre, prefill_fn, plan_pre = self._prefill_for(P_len)
        toks = np.zeros((1, S_pre), np.int32)
        toks[0, :P_len] = np.asarray(prompt, np.int32)
        first, pre_cache = prefill_fn(
            self._trunk, toks, np.array([P_len - 1], np.int32),
            np.array([req.temperature], np.float32), self._next_key())
        # admit maps ceil(P_len/page_size) pages — O(prompt), not
        # O(max_seq); each decode step maps the next page on demand
        slot = self.cache.admit(pre_cache, P_len, plan_pre=plan_pre,
                                groups=self._prefill_group_ids)
        if self.ecfg.disagg:
            # prefill-role group done: hand the paged KV (+ state rows)
            # to a decode-role group through the coded one-ppermute
            # migration.  The dispatch-side pre-check (_can_admit_next)
            # already proved a mirror-capable target exists, so routing
            # here cannot fail.
            dst = self._route_migration(slot)
            src_g = self.cache.allocator.group_of(slot)
            wire = self.cache.migrate_wire_bytes()
            slot = self.cache.migrate(slot, dst)
            self.migrations += 1
            self.migrated_wire_bytes += wire
            self._emit("on_migrate", req.rid, src_g, dst, wire)
        st = _Slot(req, list(prior), None, seq=self._admit_seq,
                   pending_first=first)
        self._admit_seq += 1
        self._slots[slot] = st
        self._pos[slot] = P_len
        self._temp[slot] = req.temperature
        self._tok_dirty.discard(slot)
        self._tok_pending[slot] = first
        self.tokens_generated += 1
        self._emit("on_admit", req.rid, slot)
        # retirement the host can predict WITHOUT the token value (count
        # and context limits) applies now so the slot is never scheduled;
        # the deferred value still folds later for the output/EOS
        if (self._n_committed(st) >= st.req.max_new_tokens
                or self._committed_pos(st) >= self.ecfg.max_seq):
            st.live = False

    def _n_committed(self, st: _Slot) -> int:
        """Tokens the request has generated as far as the host is
        concerned: the committed ``out`` plus the admit prefill's
        deferred first token (generated, value just not yet synced)."""
        return len(st.out) + (1 if st.pending_first is not None else 0)

    def _committed_pos(self, st: _Slot) -> int:
        """The slot's committed cache occupancy / next write position.

        Derived, not stored: admit leaves ``prompt + [first]`` at
        occupancy ``len(prompt)``, and every committed token advances
        both the token count and the position by one — so the
        dispatch-side ``self._pos`` (which runs ahead of the host under
        overlap) can never be confused with what has been committed.
        """
        return len(st.req.prompt) + self._n_committed(st) - 1

    def _fold_first(self, slot: int, st: _Slot) -> bool:
        """Sync the deferred admit token into host bookkeeping.

        Returns True iff the slot is still occupied by ``st`` afterwards
        (folding runs the EOS/limit retirement check the admit path
        deferred, so it may retire the slot).  No-op when nothing is
        pending.  The sync is effectively free at every call site: the
        prefill that produced the value has already been overlapped by
        at least one dispatched step (or the pipeline is idle).
        """
        if st.pending_first is None:
            return self._slots[slot] is st
        first = int(np.asarray(st.pending_first)[0])
        st.pending_first = None
        st.out.append(first)
        self._tokens[slot] = first
        if self._tok_pending.pop(slot, None) is not None:
            # the device-side feed patch never consumed this value; the
            # next feed takes it from the (now correct) host shadow
            self._tok_dirty.add(slot)
        if (self.spec_k > 0 and self.drafter_kind == "ngram"
                and st.drafter is None):
            # st.out holds the committed stream so far — prior tokens
            # carried across a work-preserving suspend plus this first
            # token — so the drafter sees the same history an
            # uninterrupted run would have fed it incrementally (the
            # heads drafter keeps no host state: drafts live on device)
            st.drafter = NGramDrafter(list(st.req.prompt) + st.out)
        self._emit("on_first_token", st.req.rid)
        self._maybe_retire(slot, first)
        return self._slots[slot] is st

    def _fold_pending(self):
        """Fold every slot still carrying a deferred first token."""
        for i, st in enumerate(self._slots):
            if st is not None and st.pending_first is not None:
                self._fold_first(i, st)

    def _maybe_retire(self, slot: int, tok: int):
        st = self._slots[slot]
        done = (len(st.out) >= st.req.max_new_tokens
                or (self.ecfg.eos_id is not None and tok == self.ecfg.eos_id)
                or self._committed_pos(st) >= self.ecfg.max_seq)
        if done:
            # evict zeroes the slot's block-table row (-1), so the stale
            # pos/token the retired row still carries into the next
            # batched step can only produce dropped writes — a recycled
            # page can never be corrupted by its previous owner.  Under
            # overlap the freed pages park in the cache's deferred-free
            # limbo until every dispatched snapshot has committed.
            st.live = False
            self.cache.evict(slot)
            self._slots[slot] = None
            self._retired.append((st.req, st.out))
            self._emit("on_finish", st.req.rid, len(st.out))

    # -- scheduling --------------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def queue_depth(self) -> int:
        """Requests admitted-but-waiting (the backpressure signal SLO
        monitors and admission routers read every tick)."""
        return len(self._queue)

    @property
    def idle(self) -> bool:
        return (not self._queue and self.num_active == 0
                and not self._inflight)

    def _live_slots(self) -> list:
        return [i for i, s in enumerate(self._slots)
                if s is not None and s.live]

    def active_slots(self) -> list:
        """Occupied slot indices, oldest admission first — the fault
        injector's victim menu (``[-1]`` is the youngest)."""
        return sorted((i for i, s in enumerate(self._slots)
                       if s is not None),
                      key=lambda i: self._slots[i].seq)

    # -- disaggregated admission / routing ---------------------------------

    def _route_migration(self, src_slot: int) -> int:
        """Pick the decode-role group that takes ``src_slot``'s KV.

        ``router="load"``: the mirror-capable candidate with the fewest
        pages mapped-or-in-limbo (limbo pages are claims the group
        already owes), ties to the lowest group id.  ``router="rr"``:
        the first mirror-capable candidate at/after a round-robin
        cursor.  ``_can_admit_next`` proved a candidate exists before
        the admission started, so exhaustion here is a scheduler bug —
        surfaced as a typed ``PagePoolExhausted``.
        """
        alloc = self.cache.allocator
        cands = [g for g in self._decode_group_ids
                 if alloc.can_migrate(src_slot, g)]
        if not cands:
            raise PagePoolExhausted(
                f"migration of slot {src_slot}: no decode group can "
                "mirror its page placement (admission pre-check raced "
                "the allocator — scheduler bug)")
        if self.ecfg.router == "rr":
            dgs = self._decode_group_ids
            n = len(dgs)
            for k in range(n):
                g = dgs[(self._rr_next + k) % n]
                if g in cands:
                    self._rr_next = (self._rr_next + k + 1) % n
                    return g
        return min(cands, key=lambda g: (alloc.pages_in_use_by_group(g)
                                         + alloc.limbo_pages_in_group(g),
                                         g))

    def _admit_ready(self, P_len: int) -> bool:
        """Exact can-this-admission-finish pre-check for a ``P_len``
        prompt against the allocator's CURRENT state.

        Colocated: limbo-aware ``can_admit``.  Disaggregated, three
        legs: a prefill-role group can take the prompt, the slot
        ``alloc`` would pick can place its pages (simulated placement),
        and some decode-role group can MIRROR that placement per shard
        and has a free slot.  Admission only starts when the whole
        prefill -> migrate chain is guaranteed, so the router never has
        to unwind a prefill — a starved target keeps the request
        queued, which IS the re-queue path.
        """
        alloc = self.cache.allocator
        if not self.ecfg.disagg:
            return alloc.can_admit(P_len)
        if not alloc.can_admit(P_len, groups=self._prefill_group_ids):
            return False
        src = alloc.peek_alloc(P_len, groups=self._prefill_group_ids)
        if src is None:
            return False
        cnt = alloc.placement_counts(alloc.group_of(src),
                                     alloc.pages_needed(P_len))
        if cnt is None:
            return False
        return any(alloc.can_place_mirror(g, cnt)
                   for g in self._decode_group_ids)

    def _can_admit_next(self) -> bool:
        """Admission gate for the queue head — limbo-aware.

        ``can_admit`` counts limbo pages as UNAVAILABLE.  The old gate
        checked the free list alone, so an admit could claim the last
        fresh pages while limbo still owed pages to the pipeline — the
        very next ``ensure`` then starved mid-flight: a typed
        ``PagePoolExhausted`` with ``preempt=False``, needless
        preemption churn / pipeline-drain bubbles with the default
        rescue path.  Deferring instead is cheap and live: every tick
        commits at least down to ``async_depth``, so limbo pages rejoin
        their free deques within ``async_depth`` ticks and the queue
        head admits as soon as the pool genuinely has room (an
        ``after_flush`` counterfactual is available on
        ``SlotAllocator.can_admit`` for schedulers that would rather
        trade the overlap bubble for earlier admission).
        """
        _, _, prompt = self._entry_parts(self._queue[0])
        return self._admit_ready(len(prompt))

    # -- faults / graceful degradation -------------------------------------

    def preempt_slot(self, slot: int, kind: str = "preempt"):
        """Evict ``slot`` and re-queue its request at the FRONT of the
        admission queue, restarting generation from scratch on re-admit.

        Restart-from-scratch keeps the house token-identity rule: under
        greedy sampling the regenerated stream is bit-identical to the
        uninterrupted run (per-slot streams are batch-independent and
        greedy ignores the PRNG key), so a preemption is invisible in
        the final output — only in the request's latency.  Tokens
        generated so far are discarded rather than resumed: resuming
        mid-stream would need the slot's KV snapshot off-device, which
        is exactly the cost preemption exists to avoid.  Pages freed
        here park in the allocator's deferred-free limbo while any
        dispatched step's snapshot still names them, and an in-flight
        step's column for this slot is discarded at commit by
        slot-object identity — safe to call mid-pipeline (the fault
        injector does).  ``on_preempt`` observers fire with
        ``(rid, kind)``; ``kind`` distinguishes ``pool_pressure`` from
        injected faults (``injected_preempt``, ``replica_loss``).
        """
        st = self._slots[slot]
        if st is None:
            raise ValueError(f"preempt_slot: slot {slot} is free")
        st.live = False
        self.cache.evict(slot)
        self._slots[slot] = None
        self._tok_pending.pop(slot, None)
        self._tok_dirty.discard(slot)
        self.preemptions += 1
        self._queue.appendleft(st.req)
        self._emit("on_preempt", st.req.rid, kind)

    def _suspend_entry(self, st: _Slot):
        """Queue entry preserving ``st``'s committed work where the
        prefill path can re-ingest it: a ``_Resume`` carrying the
        committed tokens when ``prompt + committed`` still fits the
        prefill window (and, for recurrent families, lands on a valid
        exact-length bucket and a group can hold its pages) — otherwise
        the old restart-from-scratch ``Request``.  Greedy identity holds
        either way; only the work redone differs."""
        committed = list(st.out)
        if committed:
            L = len(st.req.prompt) + len(committed)
            alloc = self.cache.allocator
            if (L <= self.prefill_len
                    and alloc.pages_needed(L) <= alloc.pages_per_group
                    and (not self._has_state
                         or L % self.plan.tp_size == 0)):
                return _Resume(st.req, committed)
        return st.req

    def suspend(self) -> list:
        """Simulated host preemption: drain the pipeline, snapshot every
        pending request, and release all slots + pages.

        Returns the entries still owed output — mid-generation slots in
        admission order, then the untouched queue — for ``resume``.
        Mid-generation requests are snapshotted WORK-PRESERVING: the
        tokens committed so far ride along as a ``_Resume`` entry and
        re-admission prefills ``prompt + committed`` instead of
        regenerating it token by token (falling back to
        restart-from-scratch only when the combined length no longer
        fits the prefill path — see ``_suspend_entry``).  Greedy token
        identity to the uninterrupted run holds in both modes; requests
        that FINISHED during the drain retire normally and are not
        suspended.  After this the engine holds no device-side request
        state: pages are back in the pool and the chained token feed is
        reset, so the caller may checkpoint, migrate, or simply
        ``resume`` in place.
        """
        self.flush()
        self._fold_pending()
        reqs = []
        for i in self.active_slots():
            st = self._slots[i]
            self.cache.evict(i)
            self._slots[i] = None
            reqs.append(self._suspend_entry(st))
        self._emit("on_suspend", [r.rid for r in reqs])
        self._tok_pending.clear()
        self._tok_dirty.clear()
        self._tok_dev = None
        self._vfeed_dev = None
        self._vpos_dev = None
        reqs.extend(self._queue)
        self._queue.clear()
        self.suspends += 1
        return reqs

    def resume(self, requests: Sequence[Request]):
        """Re-admit ``suspend``'s snapshot at the front of the queue in
        its original order; admission proceeds on the next tick."""
        for r in reversed(list(requests)):
            self._queue.appendleft(r)

    def step(self) -> list:
        """One scheduler tick: dispatch what can run, commit what must.

        Returns the requests finished this tick as (request, tokens)
        pairs.  With ``async_depth=0`` every dispatch commits
        immediately — the classic synchronous loop.  With
        ``async_depth=d > 0`` the host keeps up to ``d`` device steps in
        flight: a tick dispatches step t+1 and only then joins step
        t+1-d, so host scheduling (admission, retirement, page
        bookkeeping) runs while the device computes.  When nothing can
        be dispatched (no live slot) the pipeline drains fully so the
        engine always reaches ``idle``.

        Admission is gated on BOTH a free slot and free pool pages for
        the prompt (``can_admit``); a request that doesn't fit stays
        queued.  Before a device step launches, every scheduled slot
        maps pages covering the positions the step will write
        (alloc-on-extend) — if a live slot cannot grow because its pool
        group is empty, the engine degrades gracefully
        (``EngineConfig.preempt``, default on): drain the pipeline so
        limbo pages rejoin the pool, then evict + re-queue the YOUNGEST
        slot of the starving group and retry (``_ensure_for_step``).
        With ``preempt=False`` — or when the group holds a single live
        slot, which preemption could never help — ``PagePoolExhausted``
        propagates: the pool, not the slot count, is the binding limit,
        and the operator sized ``num_pages`` below even one request's
        demand.
        """
        dispatched = self.dispatch()
        target = self.async_depth if dispatched else 0
        while len(self._inflight) > target:
            self.commit()
        return self._drain_retired()

    def dispatch(self) -> bool:
        """Admit what fits, then LAUNCH one batched decode (or k-token
        verify) step without waiting for its tokens.  Returns True iff a
        device step was dispatched (its results surface at a later
        ``commit()``)."""
        while self._queue and self._can_admit_next():
            self._admit(self._queue.popleft())
        if self.spec_k > 0 and self.drafter_kind == "heads":
            # device-side drafting: the previous verify step already
            # emitted the next feed (accepted token + head drafts) and
            # chained positions — NO pipeline join.  Only slots retired
            # by prediction at admit (never scheduled, so no commit will
            # ever fold them) need their deferred token folded here,
            # exactly like the plain decode path below.
            for i, st in enumerate(self._slots):
                if (st is not None and not st.live
                        and st.pending_first is not None):
                    self._fold_first(i, st)
            live = self._live_slots()
            if not live:
                return False
            self._dispatch_verify_heads(live)
            return True
        if self.spec_k > 0:
            # drafting reads committed tokens: join the pipeline first
            # (the admissions above already overlapped the in-flight
            # verify step — that is the spec path's share of the win),
            # then fold every deferred admit token so the drafters and
            # the host token shadow the verify feed reads are real
            self.flush()
            self._fold_pending()
            live = self._live_slots()
            if not live:
                return False
            self._dispatch_verify(live)
            return True
        # slots retired-by-prediction at admit (max_new_tokens == 1,
        # context already full) are never scheduled, so no commit will
        # ever fold their deferred token: fold it here or they leak
        for i, st in enumerate(self._slots):
            if st is not None and not st.live and st.pending_first is not None:
                self._fold_first(i, st)
        live = self._live_slots()
        if not live:
            return False
        self._dispatch_decode(live)
        return True

    def commit(self):
        """Join the OLDEST in-flight step — the single host sync of the
        decode hot path — and apply its bookkeeping: append/accept
        tokens, retire finished requests, roll back rejected drafts,
        release deferred page frees."""
        if not self._inflight:
            raise ValueError("commit: no dispatched step in flight")
        rec = self._inflight.popleft()
        out = np.asarray(rec.out)        # host sync: the step has fully
        #                                  executed once this returns
        self.cache.note_commit()
        self.decode_steps += 1
        if rec.kind == "verify_heads":
            self._commit_verify_heads(rec, out)
        elif rec.kind == "verify":
            self._commit_verify(rec, out)
        else:
            self._commit_decode(rec, out)

    def flush(self):
        """Commit every in-flight dispatched step (drain the pipeline)."""
        while self._inflight:
            self.commit()

    def _drain_retired(self) -> list:
        """Hand the retirements accumulated so far to the caller.

        Retired (request, tokens) pairs buffer on the engine, not in a
        ``step()``-local, so a typed mid-step failure (e.g.
        ``PagePoolExhausted`` from an ``ensure``) cannot discard results
        of requests that already finished earlier in the same step —
        they surface from the next successful ``step()``.
        """
        out, self._retired = self._retired, []
        return out

    # -- dispatch side -----------------------------------------------------

    def _stage(self, arr, spec):
        """Fresh device copy of a host feed array with the step's own
        input sharding (the double buffer: the in-flight step keeps the
        previous copy, the host is free to mutate ``arr`` for the next
        tick)."""
        return jax.device_put(np.ascontiguousarray(arr),
                              NamedSharding(self.mesh, spec))

    def _token_feed(self):
        """Device token feed for the next decode dispatch.

        Chains the previous dispatch's sampled-token device array
        straight back in — the values never visit the host — and
        patches freshly admitted slots straight from their prefill's
        DEVICE first-token array (``_tok_pending``), so admission never
        syncs either: the whole prefill -> first decode chain stays on
        device.  Slots whose deferred token was folded to the host in
        the meantime re-enter from the host shadow (``_tok_dirty``).
        Slots retired between the two dispatches keep whatever the
        device array carries: their block-table rows are already -1 (or
        owned by a new occupant that is itself patched here), so the
        garbage can only produce dropped writes and discarded outputs.
        """
        if self._tok_dev is None:
            self._tok_dirty.clear()
            feed = self._stage(self._tokens, self._feed_specs["token"])
        else:
            feed = self._tok_dev
            if self._tok_dirty:
                idx = np.asarray(sorted(self._tok_dirty), np.int32)
                feed = feed.at[idx].set(self._tokens[idx])
                self._tok_dirty.clear()
        if self._tok_pending:
            for s in sorted(self._tok_pending):
                feed = feed.at[s].set(self._tok_pending[s][0])
            self._tok_pending.clear()
        return feed

    def _ensure_for_step(self, live, need):
        """Map every page the next step will write (``need(slot)`` is the
        occupancy it must cover) — with graceful degradation.

        On ``PagePoolExhausted`` (the pool, not the slot count, is the
        binding limit) and ``ecfg.preempt``: first drain the pipeline —
        deferred-free limbo pages from late retirements/rollbacks rejoin
        the pool at commit — and if the starving slot's group is STILL
        dry, evict + re-queue the YOUNGEST slot in that group and retry.
        Youngest-first preserves the progress guarantee: the oldest
        request is never the victim, so every preemption strictly
        advances the admission order and the scheduler cannot livelock.
        A group with a single live slot is never preempted against
        itself — the typed error propagates, exactly as with
        ``preempt=False`` (the operator sized ``num_pages`` below even
        one request's demand).  Returns the (possibly shrunk) live list.
        ``ensure`` is idempotent per page, so retrying the loop after a
        partial pass never double-maps.
        """
        alloc = self.cache.allocator
        while True:
            try:
                for i in live:
                    self.cache.ensure(i, need(i))
                return live
            except PagePoolExhausted:
                if not self.ecfg.preempt:
                    raise
                starving = i
            if self._inflight:
                self.flush()      # commits release limbo pages; they may
                #                   also retire slots (late EOS) or fold
                #                   deferred tokens — refresh and retry
                live = [j for j in live
                        if self._slots[j] is not None and self._slots[j].live]
                continue
            grp = alloc.group_of(starving)
            victims = [j for j in live if alloc.group_of(j) == grp]
            if len(victims) < 2:
                # preempting the sole live slot of its group would free
                # its pages only to starve again on re-admit: retry once
                # so the typed error propagates (unless the flush above
                # retired the starving slot, in which case this passes)
                for i in live:
                    self.cache.ensure(i, need(i))
                return live
            victim = max(victims, key=lambda j: self._slots[j].seq)
            self.preempt_slot(victim, kind="pool_pressure")
            live = [j for j in live if j != victim]

    def _dispatch_decode(self, live):
        # the step writes KV at position pos: map its page first.  Under
        # overlap a slot here may already be finished at a
        # still-uncommitted step (late EOS) — its page comes back
        # through the deferred-free epoch at that step's commit.
        live = self._ensure_for_step(live, lambda i: int(self._pos[i]) + 1)
        if not live:
            return
        tok = self._token_feed()
        pos = self._stage(self._pos, self._feed_specs["pos"])
        bt = self._stage(self.cache.block_table, self._feed_specs["bt"])
        clp = self._stage(self.cache.page_list_loc, self._feed_specs["clp"])
        clo = self._stage(self.cache.page_list_pos, self._feed_specs["clo"])
        temp = self._stage(self._temp, self._feed_specs["temp"])
        out, self.cache.buffers = self._decode(
            self._trunk, self.cache.buffers, tok, pos, bt, clp, clo, temp,
            self._next_key())
        self.cache.note_dispatch()
        self._tok_dev = out
        self._inflight.append(
            _InFlight("decode", [(i, self._slots[i]) for i in live], out))
        for i in live:
            st = self._slots[i]
            st.inflight += 1
            self._pos[i] += 1
            # predictable retirement (token count, context end) applies
            # at dispatch so a finished slot never gets scheduled again;
            # EOS is only discoverable at commit, one step late under
            # overlap, and that zombie step's column is discarded.
            # _n_committed counts the deferred admit token too.
            if (self._n_committed(st) + st.inflight >= st.req.max_new_tokens
                    or int(self._pos[i]) >= self.ecfg.max_seq):
                st.live = False

    def _dispatch_verify(self, live):
        """Launch one speculative step: draft k per slot, score all k+1
        positions in one batched forward.  Acceptance happens at commit.

        Under greedy sampling the committed stream is token-identical to
        ``spec_k=0``: drafts only ever get accepted when they equal the
        argmax the vanilla step would have produced, and the first
        correction token is that argmax itself.
        """
        k = self.spec_k
        n = self.ecfg.num_slots
        # the verify step writes KV at pos..pos+k (clipped at the
        # context end): map those pages before launching; the rejected
        # tail's pages roll back once acceptance is known
        live = self._ensure_for_step(
            live, lambda i: min(int(self._pos[i]) + k + 1,
                                self.ecfg.max_seq))
        if not live:
            return
        drafts = np.zeros((n, k), np.int32)
        for i in live:
            drafts[i] = self._slots[i].drafter.propose(k)
        tok_in = self._stage(
            np.concatenate([self._tokens[:, None], drafts], axis=1),
            self._feed_specs["vtoken"])
        # this feed just consumed the host token shadow for EVERY slot:
        # nothing stays dirty for a future feed
        self._tok_dirty.clear()
        pos = self._stage(self._pos, self._feed_specs["pos"])
        bt = self._stage(self.cache.block_table, self._feed_specs["bt"])
        clp = self._stage(self.cache.page_list_loc, self._feed_specs["clp"])
        clo = self._stage(self.cache.page_list_pos, self._feed_specs["clo"])
        temp = self._stage(self._temp, self._feed_specs["temp"])
        out, self.cache.buffers = self._verify(
            self._trunk, self.cache.buffers, tok_in, pos, bt, clp, clo,
            temp, self._next_key())
        self.cache.note_dispatch()
        self._inflight.append(
            _InFlight("verify", [(i, self._slots[i]) for i in live], out,
                      drafts=drafts))
        for i in live:
            self._slots[i].inflight += 1

    def _verify_feed(self):
        """Device (feed [B,K1], pos [B]) for the next heads-drafter
        verify dispatch.

        Chains the previous verify step's device-emitted feed/positions
        straight back in — drafts and acceptance never visit the host
        between dispatches.  Slots that need re-seeding patch in exactly
        like ``_token_feed``: host-folded slots (``_tok_dirty``) from
        the host shadow at their committed position, freshly admitted
        slots (``_tok_pending``) from their prefill's DEVICE first-token
        array.  A re-seeded row is ``[tok]*K1`` — repeat-token drafts,
        garbage-safe under longest-prefix acceptance (worst case the
        step degrades to vanilla decode for that slot for one step).
        """
        K1 = self.spec_k + 1
        if self._vfeed_dev is None:
            self._tok_dirty.clear()
            feed = self._stage(np.repeat(self._tokens[:, None], K1, axis=1),
                               self._feed_specs["vtoken"])
            pos = self._stage(self._pos, self._feed_specs["vpos"])
        else:
            feed, pos = self._vfeed_dev, self._vpos_dev
            if self._tok_dirty:
                idx = np.asarray(sorted(self._tok_dirty), np.int32)
                feed = feed.at[idx].set(self._tokens[idx, None])
                pos = pos.at[idx].set(self._pos[idx])
                self._tok_dirty.clear()
        if self._tok_pending:
            for s in sorted(self._tok_pending):
                feed = feed.at[s].set(self._tok_pending[s][0])
                pos = pos.at[s].set(int(self._pos[s]))
            self._tok_pending.clear()
        return feed, pos

    def _dispatch_verify_heads(self, live):
        """Launch one speculative step with DEVICE-side drafting — no
        pipeline join, so under ``async_depth > 0`` verify t+1 overlaps
        verify t exactly like plain decode steps do.

        Page mapping covers the worst case of every un-synced chain
        link: each in-flight step (plus this one) can advance a slot by
        at most spec_k+1 positions past the last COMMITTED position, so
        ``ensure`` maps up to ``pos + (k+1) * (inflight+1)``.  The
        unreclaimed tail this over-mapping leaves is bounded by
        ``(k+1) * (async_depth+1)`` positions per slot and is trimmed
        page-exactly by the chain's last commit (``st.inflight == 0``).
        """
        k = self.spec_k
        live = self._ensure_for_step(
            live, lambda i: min(
                int(self._pos[i])
                + (k + 1) * (self._slots[i].inflight + 1),
                self.ecfg.max_seq))
        if not live:
            return
        if self._inflight:
            # a verify launched over a still-un-synced step: the host
            # join the ngram drafter forces is provably gone (tests
            # assert this counter stays 0 for drafter="ngram")
            self.pipelined_dispatches += 1
        feed, pos = self._verify_feed()
        bt = self._stage(self.cache.block_table, self._feed_specs["bt"])
        clp = self._stage(self.cache.page_list_loc, self._feed_specs["clp"])
        clo = self._stage(self.cache.page_list_pos, self._feed_specs["clo"])
        temp = self._stage(self._temp, self._feed_specs["temp"])
        out, feed_next, pos_next, self.cache.buffers = self._verify(
            self.params, self.cache.buffers, feed, pos, bt, clp, clo,
            temp, self._next_key())
        self.cache.note_dispatch()
        self._vfeed_dev, self._vpos_dev = feed_next, pos_next
        self._inflight.append(
            _InFlight("verify_heads",
                      [(i, self._slots[i]) for i in live], out,
                      feed_in=feed, pos_in=pos))
        for i in live:
            self._slots[i].inflight += 1

    # -- commit side -------------------------------------------------------

    def _commit_decode(self, rec: _InFlight, out: np.ndarray):
        for i, st in rec.entries:
            if self._slots[i] is not st:
                continue     # retired at an earlier commit (late EOS),
                #              preempted, or slot re-admitted: discard
                #              the zombie column
            st.inflight -= 1
            if not self._fold_first(i, st):
                continue     # the deferred admit token was EOS: the slot
                #              retired at fold and this step's column is
                #              a zombie (its write already landed beyond
                #              the retired occupancy — dropped on device)
            tok = int(out[i])
            st.out.append(tok)
            self._tokens[i] = tok
            self.tokens_generated += 1
            self._maybe_retire(i, tok)

    def _commit_verify(self, rec: _InFlight, out: np.ndarray):
        """Accept the longest draft prefix matching the verify output
        plus the model's correction token; roll the rejected tail's
        cache occupancy back page-exactly."""
        k = self.spec_k
        drafts = rec.drafts
        for i, st in rec.entries:
            if self._slots[i] is not st:
                continue
            st.inflight -= 1
            a = 0
            while a < k and drafts[i, a] == out[i, a]:
                a += 1
            committed = 0
            for j in range(a + 1):                 # accepted drafts + fixup
                tok = int(out[i, j])
                st.out.append(tok)
                st.drafter.extend([tok])
                self._tokens[i] = tok
                self._pos[i] += 1
                self.tokens_generated += 1
                committed += 1
                if (len(st.out) >= st.req.max_new_tokens
                        or (self.ecfg.eos_id is not None
                            and tok == self.ecfg.eos_id)
                        or self._pos[i] >= self.ecfg.max_seq):
                    break
            self.cache.rollback(i, int(self._pos[i]))
            self.spec_commits += committed
            self.spec_verifies += 1
            self._maybe_retire(i, int(self._tokens[i]))

    def _commit_verify_heads(self, rec: _InFlight, out: np.ndarray):
        """Commit one heads-drafter verify step.

        The drafts this step scored lived only on device (the previous
        step's chained feed), so acceptance is recomputed here from the
        synced feed snapshot (``rec.feed_in``) against the sampled
        outputs — the same longest-prefix rule the device applied when
        it chained the NEXT step's feed and positions.  For a slot the
        host neither truncates nor retires, the committed position lands
        exactly on the chained device position, keeping every later
        in-flight step of the chain valid; truncation (max_new_tokens,
        EOS, context end) always retires the slot, so its later columns
        are zombies discarded by slot-object identity — the same
        structural safety valve the ngram path leans on.

        Page reclaim is deferred while the slot still has in-flight
        steps (they may legitimately write past this step's occupancy);
        the chain's LAST commit trims page-exactly, and eviction frees
        everything regardless.
        """
        k = self.spec_k
        feed = np.asarray(rec.feed_in)
        base = np.asarray(rec.pos_in)
        for i, st in rec.entries:
            if self._slots[i] is not st:
                continue
            st.inflight -= 1
            if not self._fold_first(i, st):
                continue
            a = 0
            while a < k and feed[i, a + 1] == out[i, a]:
                a += 1
            committed = 0
            pos = int(base[i])
            for j in range(a + 1):             # accepted drafts + fixup
                tok = int(out[i, j])
                st.out.append(tok)
                self._tokens[i] = tok
                pos += 1
                committed += 1
                self.tokens_generated += 1
                if (len(st.out) >= st.req.max_new_tokens
                        or (self.ecfg.eos_id is not None
                            and tok == self.ecfg.eos_id)
                        or pos >= self.ecfg.max_seq):
                    break
            self._pos[i] = pos
            self.spec_commits += committed
            self.spec_verifies += 1
            if st.inflight == 0:
                self.cache.rollback(i, pos)
            self._maybe_retire(i, int(self._tokens[i]))

    @property
    def mean_accepted_len(self) -> float:
        """Mean tokens committed per (slot, verify-step) — >1.0 means the
        drafter is paying for itself."""
        return self.spec_commits / max(self.spec_verifies, 1)

    def run(self, requests: Sequence[Request], max_steps: int = 100000,
            on_step=None):
        """Serve ``requests`` to completion; {rid: generated tokens}.

        ``on_step`` (optional) is called as ``on_step(self)`` after
        every scheduler tick — benches timestamp per-step latency
        through it instead of re-implementing this drive loop (and
        losing its typed ``SchedulerStall`` diagnostics).
        """
        for r in requests:
            self.submit(r)
        results = {}
        for _ in range(max_steps):
            for req, out in self.step():
                results[req.rid] = out
            if on_step is not None:
                on_step(self)
            if self.idle:
                break
        if not self.idle:
            raise SchedulerStall(
                f"run: {self.num_active} slots still active, "
                f"{len(self._queue)} requests queued and "
                f"{len(self._inflight)} steps in flight after "
                f"{max_steps} steps")
        return results

    def warmup(self, prompt: Sequence[int]):
        """Compile the prefill/insert/decode/verify programs off the
        clock by serving one throwaway request, then zero the throughput
        stats.  The throwaway uses the reserved ``WARMUP_RID`` sentinel,
        which no user-supplied rid can equal."""
        self.run([Request(rid=WARMUP_RID, prompt=prompt, max_new_tokens=2)])
        self.reset_stats()

    def reset_stats(self):
        """Zero the throughput counters.

        Any in-flight dispatched step is committed FIRST: a pipelined
        step straddling the reset would otherwise surface its tokens
        (and its device time) inside the measured run — warmup would
        leak work into the numbers it exists to keep clean.  Results
        retired by the flush stay buffered for the next ``step()``.
        """
        self.flush()
        self.tokens_generated = 0
        self.decode_steps = 0
        self.spec_commits = 0
        self.spec_verifies = 0
        self.pipelined_dispatches = 0
        self.preemptions = 0
        self.suspends = 0
        self.migrations = 0
        self.migrated_wire_bytes = 0
        # the pool high-water mark is a stat too: warmup's throwaway
        # admission must not overstate the measured run's peak
        self.cache.peak_pages_in_use = self.cache.allocator.pages_in_use

    # -- introspection -----------------------------------------------------

    def _wire_stats(self, program, ins, tokens_per_step: float,
                    params=None):
        """lower+compile ``program`` on its input specs and parse the ICI
        collectives; (CollectiveStats, total wire bytes per token across
        the mesh at ``tokens_per_step`` tokens committed per step).
        ``params`` defaults to the trunk-only tree (what every program
        except the heads verify step compiles against)."""
        from ..launch import roofline as RL
        lowered = program.lower(
            self._trunk if params is None else params,
            self.cache.buffers, ins["token"], ins["pos"],
            ins["bt"], ins["clp"], ins["clo"], ins["temp"], ins["key"])
        stats = RL.parse_collectives(lowered.compile().as_text())
        ndev = self.plan.dp_size * self.plan.tp_size
        per_tok = stats.wire_bytes * ndev / max(tokens_per_step, 1e-9)
        return stats, per_tok

    def decode_wire_stats(self):
        """Parse the compiled batched decode step's collectives.

        Returns (CollectiveStats, wire_bytes_per_token): per-device ICI
        bytes of ONE decode step, scaled to total bytes per generated
        token across the mesh.
        """
        ins, _ = serve_decode_input_specs(self.plan, self.ecfg.page_size,
                                          self.num_pages)
        return self._wire_stats(self._decode, ins, self.ecfg.num_slots)

    def verify_wire_stats(self, accepted_len: float = 1.0):
        """Parse the compiled k-token verify step's collectives.

        Returns (CollectiveStats, wire_bytes_per_token): per-device ICI
        bytes of ONE verify step, scaled to total bytes per *committed*
        token across the mesh at the given mean accepted length.  The
        verify step moves ~(spec_k+1)x the decode step's D-space
        activation bytes through the same coded boundaries — the traffic
        multiplier the spike wire absorbs; dividing by ``accepted_len``
        shows what the wire actually pays per token kept.
        """
        if self._verify is None:
            raise EngineConfigError("verify_wire_stats: spec_k == 0")
        ins, _ = serve_verify_input_specs(self.plan_ver, self.spec_k,
                                          self.ecfg.page_size,
                                          self.num_pages)
        return self._wire_stats(
            self._verify, ins, self.ecfg.num_slots * accepted_len,
            params=(self.params if self.drafter_kind == "heads"
                    else None))

    def wire_stream_profile(self):
        """Per-collective wire streams of each compiled step kind.

        Returns ``{step kind -> {stream kind -> bytes}}`` where the
        bytes are one device step's TOTAL die-to-die traffic across the
        mesh, split by semantic stream (``psum`` / ``head_all_gather`` /
        ``partial_combine`` / ... — the ``CollectiveStats.by_stream``
        classification from ``launch.roofline.parse_collectives``).  The
        ``"decode"`` entry is always present; ``"verify"`` joins it when
        ``spec_k > 0``, so a monitor fed this profile prices BOTH step
        kinds the engine can emit (a recurrent-family fallback run only
        ever ticks ``"decode"``).  Feed it to
        ``SLOMonitor(wire_streams_per_step=...)``: the step trace then
        carries the per-collective breakdown the cycle-level NoC
        co-simulation (``repro.sim.noc.NocSim.simulate_trace``) maps
        onto boundary serdes ports, and the scalar ``wire_bytes`` stays
        the sum of the streams.
        """
        ndev = self.plan.dp_size * self.plan.tp_size
        stats, _ = self.decode_wire_stats()
        prof = {"decode": {k: v * ndev
                           for k, v in sorted(stats.by_stream.items())}}
        if self.spec_k > 0:
            vstats, _ = self.verify_wire_stats(1.0)
            prof["verify"] = {k: v * ndev
                              for k, v in sorted(vstats.by_stream.items())}
        return prof

    def pool_stats(self) -> dict:
        """KV pool occupancy + bytes, next to the dense baseline.

        ``kv_bytes_dense`` is what the pre-paging layout reserved
        (every slot charged ``pages_per_slot`` pages up front) — the
        ``kv_bytes_pool``/``kv_bytes_dense`` ratio is the HBM the block
        table frees for more slots at equal hardware.  ``pressure`` is
        the fraction of the pool mapped or in limbo (1.0 = the next
        alloc-on-extend is at the mercy of preemption) — the signal SLO
        monitors trend per step.
        """
        alloc = self.cache.allocator
        return {
            "page_size": alloc.page_size,
            "num_pages": alloc.num_pages,
            "pages_in_use": alloc.pages_in_use,
            "pages_in_limbo": alloc.pages_in_limbo,
            "pressure": alloc.pressure,
            "peak_pages_in_use": self.cache.peak_pages_in_use,
            "kv_bytes_mapped": self.cache.kv_bytes_mapped(),
            "kv_bytes_pool": self.cache.kv_bytes_pool(),
            "kv_bytes_dense": self.cache.kv_bytes_dense_reservation(),
        }
