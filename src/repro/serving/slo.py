"""Serving observability: per-request SLOs, fault injection, BENCH JSON.

Three pieces, all host-side and engine-agnostic (they attach to a
``ServingEngine`` through its observer hooks plus the ``on_step``
callback — no hot-path device work):

``SLOMonitor``
    Records the request lifecycle (submit -> first token -> finish,
    preemptions/restarts in between) and one ``StepEvent`` per scheduler
    tick (host latency, step kind, tokens committed, queue depth, pool
    pressure, wire bytes — split per collective stream when the engine's
    ``wire_stream_profile()`` is registered, so the step trace can drive
    the cycle-level NoC co-simulation instead of the closed-form EMIO
    bridge).  ``report()`` reduces that to the production
    questions: TTFT/TPOT/step-latency p50/p95/p99 and SLO *attainment*
    — the fraction of finished requests meeting the ``SLOTargets`` —
    plus queue/pool pressure peaks and fault counts.  TTFT is measured
    from the ORIGINAL submit, so a preempted-and-re-served request pays
    its requeue penalty in the percentiles instead of hiding it.

``FaultInjector``
    A seeded chaos source driven once per tick: pool-pressure-style
    preemption of the youngest slot (``p_preempt``), replica loss of a
    random active slot (``p_replica_loss``, pages reclaimed + request
    re-admitted from the queue), and simulated host preemption
    (``p_suspend``: drain the pipeline, snapshot every in-flight
    request, resume).  All three ride the engine's graceful-degradation
    paths, which the fault fuzz (tests/test_faults.py) gates on greedy
    token-identity with an uninterrupted run.

``BENCH_serve.json`` emitter
    ``make_bench_payload`` / ``write_bench`` / ``load_bench`` define the
    in-repo perf-trajectory artifact (schema ``bench_serve/v1``): run
    config + per-codec tokens/s, stepus/TTFT/TPOT percentiles, wire
    KB/token, SLO attainment, fault counters.  ``validate_bench`` is
    the schema gate CI's bench-smoke lane fails on, so the trajectory
    can't silently rot.
"""
from __future__ import annotations

import dataclasses
import json
import time
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from .engine import WARMUP_RID

__all__ = ["BENCH_SCHEMA", "FaultInjector", "FaultPlan", "SLOMonitor",
           "SLOTargets", "StepEvent", "load_bench", "make_bench_payload",
           "percentiles", "validate_bench", "write_bench"]

#: Schema tag every BENCH_serve.json carries; bump on breaking changes.
BENCH_SCHEMA = "bench_serve/v1"


# ---------------------------------------------------------------------------
# percentile helpers
# ---------------------------------------------------------------------------


def percentiles(xs: Sequence[float]) -> Dict[str, float]:
    """{"p50","p95","p99","mean","n"} of ``xs`` (zeros when empty)."""
    xs = np.asarray(list(xs), np.float64)
    if xs.size == 0:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
    p50, p95, p99 = np.percentile(xs, [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "mean": float(xs.mean()), "n": int(xs.size)}


# ---------------------------------------------------------------------------
# SLO monitor
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOTargets:
    """Per-request targets the attainment numbers are judged against."""

    ttft_ms: float = 500.0           # submit -> first token
    tpot_ms: float = 100.0           # mean per-token after the first


@dataclasses.dataclass
class StepEvent:
    """One scheduler tick's measurements."""

    t: float                         # monitor-clock timestamp (s)
    dt: float                        # host wall time since previous tick
    kind: str                        # "decode" | "verify"
    tokens: int                      # tokens committed during the tick
    queue_depth: int
    active: int
    pages_in_use: int
    pages_in_limbo: int
    wire_bytes: float                # total die-to-die bytes the tick's
    #                                  device step moved (0 if unknown),
    #                                  INCLUDING any KV migration below
    mig_bytes: float = 0.0           # disagg KV-migration bytes folded
    #                                  into this tick's wire_bytes
    accepted_len: float = 0.0        # mean tokens committed per (slot,
    #                                  verify-step) this tick — 0.0 on
    #                                  non-speculative ticks
    #: per-collective split of ``wire_bytes`` (stream kind -> bytes:
    #: psum / head_all_gather / partial_combine / kv_migrate / ...);
    #: always sums to ``wire_bytes``, empty when only the scalar was
    #: registered
    wire_streams: Dict[str, float] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class _ReqRecord:
    cls: str
    prompt_len: int
    t_submit: float                  # ORIGINAL submit (restarts keep it)
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    n_tokens: int = 0
    restarts: int = 0


class SLOMonitor:
    """Engine observer + ``on_step`` recorder; see module docstring.

    Attach with ``engine.observers.append(monitor)`` (or pass it to
    ``workload.replay``) and call ``monitor.on_step(engine)`` after
    every tick — ``engine.run(..., on_step=monitor.on_step)`` does.
    ``wire_streams_per_step`` maps step kind -> {stream kind -> bytes}
    of one compiled step (from ``engine.wire_stream_profile()``), so
    every tick records a per-collective ``wire_streams`` breakdown the
    cycle-level NoC co-simulation (``repro.sim.noc.NocSim.
    simulate_trace``) can map onto serdes ports; ``wire_bytes_per_step``
    is the scalar-only legacy form (kept for callers without a stream
    profile — the closed-form ``emio_cost_from_trace`` bridge needs only
    the scalar).  A tick whose step kind has NO registered bytes would
    silently price at 0, so it warns (once per kind): register every
    kind the engine can emit — ``decode`` AND ``verify``.
    """

    def __init__(self, targets: Optional[SLOTargets] = None,
                 wire_bytes_per_step: Optional[Dict[str, float]] = None,
                 clock=time.perf_counter,
                 wire_streams_per_step: Optional[
                     Dict[str, Dict[str, float]]] = None):
        self.targets = targets or SLOTargets()
        self.wire_streams_per_step = {
            k: dict(v) for k, v in (wire_streams_per_step or {}).items()}
        self.wire_bytes_per_step = dict(wire_bytes_per_step or {})
        for k, streams in self.wire_streams_per_step.items():
            self.wire_bytes_per_step.setdefault(
                k, float(sum(streams.values())))
        self._warned_kinds: set = set()
        self.clock = clock
        self.requests: Dict[object, _ReqRecord] = {}
        self.steps: List[StepEvent] = []
        self.preemptions = 0
        self.suspends = 0
        self.migrations = 0
        self.migrated_bytes = 0.0
        self._t_last: Optional[float] = None
        self._tokens_last = 0
        self._steps_last = 0
        self._pending_mig_bytes = 0.0
        self._spec_commits_last = 0
        self._spec_verifies_last = 0
        self._spec_k = 0
        #: per-tick mean accepted-draft lengths (speculative ticks only)
        self.accepted_lens: List[float] = []

    # -- engine observer hooks (duck-typed; all optional) ------------------

    def on_submit(self, rid, prompt_len: int):
        if rid is WARMUP_RID:
            return
        rec = self.requests.get(rid)
        if rec is None:
            cls = rid.split("/")[1] if (isinstance(rid, str)
                                        and rid.count("/") >= 2) else ""
            self.requests[rid] = _ReqRecord(cls, prompt_len, self.clock())
        else:
            # re-submit after suspend/preempt: the request restarts from
            # scratch but its clock does NOT — the requeue penalty is
            # the SLO story, so t_submit stays and first/finish clear
            rec.restarts += 1
            rec.t_first = rec.t_finish = None
            rec.n_tokens = 0

    def on_first_token(self, rid):
        rec = self.requests.get(rid)
        if rec is not None and rec.t_first is None:
            rec.t_first = self.clock()

    def on_finish(self, rid, n_tokens: int):
        rec = self.requests.get(rid)
        if rec is not None:
            rec.t_finish = self.clock()
            rec.n_tokens = n_tokens

    def on_preempt(self, rid, kind: str):
        self.preemptions += 1
        rec = self.requests.get(rid)
        if rec is not None:
            rec.restarts += 1
            rec.t_first = rec.t_finish = None
            rec.n_tokens = 0

    def on_suspend(self, rids: Sequence):
        """One drain+snapshot event; ``rids`` are the mid-generation
        requests losing their work — they restart from scratch on
        resume, so their first-token clocks reset (TTFT keeps measuring
        from the ORIGINAL submit, same as preemption)."""
        self.suspends += 1
        for rid in rids:
            rec = self.requests.get(rid)
            if rec is not None:
                rec.restarts += 1
                rec.t_first = rec.t_finish = None
                rec.n_tokens = 0

    def on_migrate(self, rid, src_group: int, dst_group: int,
                   wire_bytes: int):
        """Disaggregated KV handoff: ``wire_bytes`` moved from the
        prefill group to the decode group for ``rid``.  Migrations fire
        during admission, between ticks — the bytes are held pending and
        folded into the NEXT ``StepEvent``'s ``wire_bytes`` (and
        surfaced separately as ``mig_bytes``) so the EMIO co-simulation
        prices them with the step that paid for them."""
        self.migrations += 1
        self.migrated_bytes += wire_bytes
        self._pending_mig_bytes += wire_bytes

    # -- per-tick recorder -------------------------------------------------

    def on_step(self, engine):
        now = self.clock()
        dt = 0.0 if self._t_last is None else now - self._t_last
        self._t_last = now
        kind = "verify" if engine.spec_k > 0 else "decode"
        d_tokens = engine.tokens_generated - self._tokens_last
        self._tokens_last = engine.tokens_generated
        d_steps = engine.decode_steps - self._steps_last
        self._steps_last = engine.decode_steps
        alloc = engine.cache.allocator
        mig, self._pending_mig_bytes = self._pending_mig_bytes, 0.0
        # per-step accepted-draft length: how many of this tick's verify
        # participations' tokens the drafter paid for (the acceptance
        # signal the drafter benches compare ngram vs heads on).
        # getattr: observers are duck-typed and host-side stub engines
        # (tests, external drivers) may not carry the spec counters
        self._spec_k = max(self._spec_k, int(engine.spec_k))
        commits = getattr(engine, "spec_commits", 0)
        verifies = getattr(engine, "spec_verifies", 0)
        d_acc = commits - self._spec_commits_last
        d_ver = verifies - self._spec_verifies_last
        self._spec_commits_last = commits
        self._spec_verifies_last = verifies
        acc_len = d_acc / d_ver if d_ver > 0 else 0.0
        if d_ver > 0:
            self.accepted_lens.append(acc_len)
        if (d_steps > 0 and self.wire_bytes_per_step
                and kind not in self.wire_bytes_per_step
                and kind not in self._warned_kinds):
            # a registered-but-incomplete pricing table would silently
            # record 0 wire bytes for every tick of this kind, skewing
            # the co-simulation — warn once per kind instead
            self._warned_kinds.add(kind)
            warnings.warn(
                f"SLOMonitor: step kind {kind!r} has no registered wire "
                f"bytes (known: {sorted(self.wire_bytes_per_step)}); its "
                "ticks are priced at 0 bytes — register every kind the "
                "engine can emit (decode AND verify)", RuntimeWarning,
                stacklevel=2)
        base = self.wire_bytes_per_step.get(kind, 0.0) * d_steps
        if kind in self.wire_streams_per_step:
            streams = {k: v * d_steps for k, v
                       in self.wire_streams_per_step[kind].items()}
        elif base > 0:
            streams = {"total": base}
        else:
            streams = {}
        if mig > 0:
            streams["kv_migrate"] = streams.get("kv_migrate", 0.0) + mig
        self.steps.append(StepEvent(
            t=now, dt=dt, kind=kind, tokens=max(d_tokens, 0),
            queue_depth=engine.queue_depth, active=engine.num_active,
            pages_in_use=alloc.pages_in_use,
            pages_in_limbo=alloc.pages_in_limbo,
            wire_bytes=base + mig,
            mig_bytes=mig, accepted_len=acc_len, wire_streams=streams))

    def _flush_pending_mig(self):
        """Fold migration bytes still pending after the LAST tick into a
        terminal ``kind="drain"`` event so they are never dropped from
        wire accounting (a migration admitted on the final tick has no
        following ``on_step`` to absorb it).  ``dt=0.0`` keeps the event
        out of the step-latency percentiles."""
        mig, self._pending_mig_bytes = self._pending_mig_bytes, 0.0
        if mig <= 0:
            return
        last = self.steps[-1] if self.steps else None
        self.steps.append(StepEvent(
            t=self._t_last if self._t_last is not None else self.clock(),
            dt=0.0, kind="drain", tokens=0,
            queue_depth=last.queue_depth if last else 0,
            active=last.active if last else 0,
            pages_in_use=last.pages_in_use if last else 0,
            pages_in_limbo=last.pages_in_limbo if last else 0,
            wire_bytes=mig, mig_bytes=mig,
            wire_streams={"kv_migrate": mig}))

    # -- reductions --------------------------------------------------------

    def _finished(self) -> List[_ReqRecord]:
        return [r for r in self.requests.values()
                if r.t_finish is not None and r.t_first is not None]

    def report(self) -> dict:
        """Structured SLO report (the per-codec payload of BENCH JSON)."""
        self._flush_pending_mig()
        fin = self._finished()
        t = self.targets
        ttft = [(r.t_first - r.t_submit) * 1e3 for r in fin]
        tpot = [(r.t_finish - r.t_first) / (r.n_tokens - 1) * 1e3
                for r in fin if r.n_tokens > 1]
        ok_ttft = [r for r in fin
                   if (r.t_first - r.t_submit) * 1e3 <= t.ttft_ms]
        ok_tpot = [r for r in fin if r.n_tokens <= 1
                   or (r.t_finish - r.t_first) / (r.n_tokens - 1) * 1e3
                   <= t.tpot_ms]
        tpot_ids = {id(r) for r in ok_tpot}
        ok_both = [r for r in ok_ttft if id(r) in tpot_ids]
        n = max(len(fin), 1)
        steps = [s for s in self.steps if s.dt > 0]
        tokens = sum(r.n_tokens for r in fin)
        span = (self.steps[-1].t - self.steps[0].t
                if len(self.steps) > 1 else 0.0)
        return {
            "requests": {
                "submitted": len(self.requests),
                "finished": len(fin),
                "restarts": sum(r.restarts for r in self.requests.values()),
            },
            "tokens_per_s": tokens / span if span > 0 else 0.0,
            "ttft_ms": percentiles(ttft),
            "tpot_ms": percentiles(tpot),
            "step_us": percentiles([s.dt * 1e6 for s in steps]),
            "queue_depth": {
                "mean": float(np.mean([s.queue_depth for s in self.steps]))
                if self.steps else 0.0,
                "max": max((s.queue_depth for s in self.steps), default=0),
            },
            "pool": {
                "peak_pages_in_use": max((s.pages_in_use
                                          for s in self.steps), default=0),
                "peak_pages_in_limbo": max((s.pages_in_limbo
                                            for s in self.steps), default=0),
            },
            "slo": {
                "ttft_target_ms": t.ttft_ms,
                "tpot_target_ms": t.tpot_ms,
                "ttft_attainment": len(ok_ttft) / n,
                "tpot_attainment": len(ok_tpot) / n,
                "attainment": len(ok_both) / n,
            },
            "faults": {
                "preemptions": self.preemptions,
                "suspends": self.suspends,
            },
            # accepted-draft stats (all-zero on non-speculative runs):
            # accepted_len counts the correction token too, so rate =
            # (accepted_len - 1) / spec_k is the fraction of DRAFTS kept
            "acceptance": {
                "accepted_len": percentiles(self.accepted_lens),
                "rate": (max(float(np.mean(self.accepted_lens)) - 1.0, 0.0)
                         / self._spec_k
                         if self.accepted_lens and self._spec_k else 0.0),
            },
            "migration": {
                "count": self.migrations,
                "kb_total": self.migrated_bytes / 1e3,
                "kb_per_request": (self.migrated_bytes / 1e3
                                   / max(len(fin), 1)),
            },
        }

    def per_class_report(self) -> dict:
        """TTFT/TPOT percentiles split by request class (multi-tenant
        traces encode the class in the rid: ``t<seed>/<class>/<idx>``)."""
        out: dict = {}
        for cls in sorted({r.cls for r in self._finished()}):
            sub = [r for r in self._finished() if r.cls == cls]
            out[cls] = {
                "finished": len(sub),
                "ttft_ms": percentiles(
                    [(r.t_first - r.t_submit) * 1e3 for r in sub]),
                "tpot_ms": percentiles(
                    [(r.t_finish - r.t_first) / (r.n_tokens - 1) * 1e3
                     for r in sub if r.n_tokens > 1]),
            }
        return out

    # -- step-trace export (NoC co-simulation bridge) ----------------------

    def step_trace(self) -> List[dict]:
        """Per-tick records for ``--trace-out`` / the NoC bridge:
        each dict carries the fields the cycle-level co-simulation
        (``NocSim.simulate_trace``: ``wire_streams``, ``tokens``) and
        the closed-form bridge (``emio_cost_from_trace``:
        ``wire_bytes``, ``tokens``) consume, plus scheduling context."""
        self._flush_pending_mig()
        return [{"t": s.t, "dt_us": s.dt * 1e6, "kind": s.kind,
                 "tokens": s.tokens, "queue_depth": s.queue_depth,
                 "active": s.active, "pages_in_use": s.pages_in_use,
                 "pages_in_limbo": s.pages_in_limbo,
                 "wire_bytes": s.wire_bytes, "mig_bytes": s.mig_bytes,
                 "accepted_len": s.accepted_len,
                 "wire_streams": dict(s.wire_streams)}
                for s in self.steps]

    def write_trace(self, path: str):
        """Write the step trace as JSON lines (one tick per line)."""
        with open(path, "w") as f:
            for rec in self.step_trace():
                f.write(json.dumps(rec) + "\n")


def load_trace(path: str) -> List[dict]:
    """Read a ``write_trace`` JSONL file back (the NoC bridge's input)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded per-tick fault probabilities (at most one fault per tick).

    The draws come from one ``RandomState(seed)`` consumed once per
    tick, so a plan replayed over the same deterministic schedule
    injects the same faults at the same ticks — which is what lets the
    fault fuzz assert bit-identical greedy streams.
    """

    seed: int = 0
    p_preempt: float = 0.0           # evict + re-queue the youngest slot
    p_replica_loss: float = 0.0      # evict + re-queue a random slot
    p_suspend: float = 0.0           # drain + snapshot + resume
    max_faults: int = 1 << 30

    def __post_init__(self):
        if self.p_preempt + self.p_replica_loss + self.p_suspend > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")


class FaultInjector:
    """Drives a ``FaultPlan``, one roll per tick.

    Two consumers share the same seeded fault timeline: attach as a
    serving-engine observer (``on_step`` preempts/suspends slots) or
    pass to ``runtime.ft.TrainLoop.run(injector=...)``, which maps the
    same kinds onto the training runtime — ``preempt`` -> the SIGTERM
    checkpoint+clean-exit path, ``replica_loss`` -> restore from the
    newest committed checkpoint and replay, ``suspend`` -> an injected
    straggler tick for the EWMA watch.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = np.random.RandomState(plan.seed)
        self.injected = {"preempt": 0, "replica_loss": 0, "suspend": 0}

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def next_fault(self):
        """Roll this tick's fault dice WITHOUT touching an engine.

        Returns ``(kind, pick)`` where ``kind`` is ``"preempt"`` /
        ``"replica_loss"`` / ``"suspend"`` / ``None`` and ``pick`` a
        second uniform draw for victim selection.  ALWAYS consumes
        exactly two draws, whether or not a fault lands — the fault
        schedule stays a pure function of the tick index, independent
        of consumer state.  ``on_step`` (serving) and
        ``runtime.ft.TrainLoop`` (training) both drive their fault
        machinery off this one roll, so a seeded plan replays the same
        fault timeline into either runtime.
        """
        p = self.plan
        u, pick = self.rng.rand(), self.rng.rand()
        if self.total_injected >= p.max_faults:
            return None, pick
        if u >= p.p_preempt + p.p_replica_loss + p.p_suspend:
            return None, pick
        if u < p.p_preempt:
            return "preempt", pick
        if u < p.p_preempt + p.p_replica_loss:
            return "replica_loss", pick
        return "suspend", pick

    def on_step(self, engine):
        kind, pick = self.next_fault()
        if kind is None:
            return
        active = engine.active_slots()
        if kind == "preempt":
            if len(active) >= 1:
                engine.preempt_slot(active[-1], kind="injected_preempt")
                self.injected["preempt"] += 1
        elif kind == "replica_loss":
            if len(active) >= 1:
                slot = active[int(pick * len(active)) % len(active)]
                engine.preempt_slot(slot, kind="replica_loss")
                self.injected["replica_loss"] += 1
        else:
            if len(active) >= 1 or engine.queue_depth:
                engine.resume(engine.suspend())
                self.injected["suspend"] += 1


# ---------------------------------------------------------------------------
# BENCH_serve.json: the in-repo perf-trajectory artifact
# ---------------------------------------------------------------------------

_PCTL_KEYS = ("p50", "p95", "p99")


def make_bench_payload(run: dict, results: Dict[str, dict],
                       created: Optional[str] = None) -> dict:
    """Assemble (and validate) a ``bench_serve/v1`` payload.

    ``run`` is the full engine/workload configuration; ``results`` maps
    codec name -> per-codec result dict — ``tokens_per_s``, ``step_us``
    / ``ttft_ms`` / ``tpot_ms`` percentile dicts, ``wire_kb_per_tok``,
    an ``slo`` block with targets + attainment, and a ``faults`` block
    (an ``SLOMonitor.report()`` plus ``wire_kb_per_tok`` satisfies it).
    """
    payload = {"schema": BENCH_SCHEMA, "run": dict(run),
               "results": results}
    if created is not None:
        payload["created"] = created
    validate_bench(payload)
    return payload


def _need(obj: dict, key: str, where: str, typ=None):
    if not isinstance(obj, dict) or key not in obj:
        raise ValueError(f"BENCH schema: missing {where}.{key}")
    v = obj[key]
    if typ is not None and not isinstance(v, typ):
        raise ValueError(
            f"BENCH schema: {where}.{key} must be {typ}, got {type(v)}")
    return v


def _need_pctl(obj: dict, key: str, where: str):
    d = _need(obj, key, where, dict)
    for p in _PCTL_KEYS:
        _need(d, p, f"{where}.{key}", (int, float))
    return d


def validate_bench(payload: dict):
    """Raise ``ValueError`` unless ``payload`` is a valid bench_serve/v1
    document.  CI's bench-smoke lane runs this against the emitted
    ``BENCH_serve.json`` so a schema regression fails the build."""
    if _need(payload, "schema", "payload", str) != BENCH_SCHEMA:
        raise ValueError(
            f"BENCH schema: expected {BENCH_SCHEMA!r}, "
            f"got {payload['schema']!r}")
    run = _need(payload, "run", "payload", dict)
    if not run:
        raise ValueError("BENCH schema: run config must be non-empty")
    results = _need(payload, "results", "payload", dict)
    if not results:
        raise ValueError("BENCH schema: results must be non-empty")
    for codec, res in results.items():
        w = f"results[{codec}]"
        _need(res, "tokens_per_s", w, (int, float))
        _need(res, "wire_kb_per_tok", w, (int, float))
        for blk in ("step_us", "ttft_ms", "tpot_ms"):
            _need_pctl(res, blk, w)
        slo = _need(res, "slo", w, dict)
        for k in ("ttft_target_ms", "tpot_target_ms", "attainment"):
            v = _need(slo, k, f"{w}.slo", (int, float))
            if k == "attainment" and not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"BENCH schema: {w}.slo.attainment {v} not in [0,1]")
        faults = _need(res, "faults", w, dict)
        _need(faults, "preemptions", f"{w}.faults", int)
        if "cosim" in res:
            _validate_cosim(res["cosim"], f"{w}.cosim")


def _validate_cosim(cosim: dict, where: str):
    """Schema + invariant gate for the optional per-codec ``cosim``
    block (``--cosim`` benches): cycle-level NoC figures must be
    present, numeric, and bound the closed-form EMIO figure from
    above — the simulator models strictly more (per-stream serdes
    batching, deserialize, hop fill) than eq (8)."""
    if not isinstance(cosim, dict):
        raise ValueError(f"BENCH schema: {where} must be a dict")
    for k in ("joules_per_token", "noc_cycles_per_token",
              "noc_us_per_token", "emio_closed_form_cycles_per_token"):
        _need(cosim, k, where, (int, float))
    energy = _need(cosim, "energy_breakdown", where, dict)
    for k in ("PE", "MEM", "Router", "EMIO"):
        _need(energy, k, f"{where}.energy_breakdown", (int, float))
    if (cosim["noc_cycles_per_token"] + 1e-9
            < cosim["emio_closed_form_cycles_per_token"]):
        raise ValueError(
            f"BENCH schema: {where} cycle-level "
            f"noc_cycles_per_token={cosim['noc_cycles_per_token']} below "
            "closed-form emio_closed_form_cycles_per_token="
            f"{cosim['emio_closed_form_cycles_per_token']} — the "
            "simulator must upper-bound eq (8)")


def write_bench(path: str, payload: dict):
    """Validate then write ``BENCH_serve.json`` (pretty, stable keys)."""
    validate_bench(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def load_bench(path: str) -> dict:
    """Read + validate a ``BENCH_serve.json``; the CI gate."""
    with open(path) as f:
        payload = json.load(f)
    validate_bench(payload)
    return payload
