"""Paged/slotted KV-and-state cache for the batched serving engine.

Device layout is slot-major: every cache leaf carries the full slot
batch — attention KV ``[U, slots, S_max, Hkv, dh]`` seq-sharded over the
context-parallel axes, recurrent state (SSM / xLSTM / RWKV) ``[U, slots,
...]`` — allocated once at engine start and donated through every decode
step, so serving runs at constant memory with zero per-request
allocation.

The host side is a ``SlotAllocator``: a free-list of request slots plus
page-granular occupancy accounting (``page_size`` positions per page).
Pages are an accounting/scheduling granularity — the device tensors are
slot-granular; true block-table indirection inside the attention kernel
is a follow-on (ROADMAP §Serving).

``insert`` splices a freshly prefilled single-request cache into a slot
in place (donated buffers): state leaves are a slot-row write; KV leaves
additionally re-align the prefill's seq sharding onto the decode cache's
when the prefill length is shorter than ``max_seq`` (an all_gather of
the one request's KV over the cp axis — the natural admit cost).
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.specs import CellPlan, cache_specs

_KV_KEYS = ("kv", "cross_kv")


class SlotAllocator:
    """Free-list slot allocation + page-granular occupancy accounting."""

    def __init__(self, num_slots: int, max_seq: int, page_size: int = 64):
        assert num_slots > 0 and page_size > 0
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = -(-max_seq // page_size)
        self._free = deque(range(num_slots))
        self._len = np.zeros(num_slots, np.int64)   # current seq occupancy

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, seq_len: int) -> int:
        """Claim a slot for a request currently holding ``seq_len`` tokens."""
        if not self._free:
            raise RuntimeError("no free slots")
        if not 0 < seq_len <= self.max_seq:
            raise ValueError(f"seq_len {seq_len} not in (0, {self.max_seq}]")
        slot = self._free.popleft()
        self._len[slot] = seq_len
        return slot

    def extend(self, slot: int, n: int = 1):
        self._len[slot] = min(self._len[slot] + n, self.max_seq)

    def rollback(self, slot: int, new_len: int):
        """Roll a slot's occupancy back to ``new_len`` positions.

        Speculative decoding writes KV for every draft position before
        acceptance is known; the scheduler calls this to return the
        rejected tail's pages.  Only shrinking (or no-op) is legal —
        growth goes through ``extend``.
        """
        if not 0 < new_len <= self._len[slot]:
            raise ValueError(
                f"rollback slot {slot} to {new_len}: occupancy is "
                f"{int(self._len[slot])} (must shrink to a positive length)")
        self._len[slot] = new_len

    def free(self, slot: int):
        if self._len[slot] <= 0:
            # typed (not assert): a double free surviving `python -O`
            # would put the slot on the free list twice and hand it to
            # two requests at once
            raise ValueError(f"slot {slot} already free")
        self._len[slot] = 0
        self._free.append(slot)

    def pages_used(self, slot: int) -> int:
        return int(-(-self._len[slot] // self.page_size))

    @property
    def total_pages(self) -> int:
        return self.num_slots * self.pages_per_slot

    @property
    def pages_in_use(self) -> int:
        return int(sum(self.pages_used(s) for s in range(self.num_slots)))


def _is_kv_path(path) -> bool:
    return any(getattr(p, "key", None) in _KV_KEYS for p in path)


def _init_leaf(path, s):
    # rwkv's log-space max-tracker must start at -inf, everything else 0
    if any(getattr(p, "key", None) == "pp" for p in path):
        return jnp.full(s.shape, -1e30, s.dtype)
    return jnp.zeros(s.shape, s.dtype)


def make_init_fn(plan: CellPlan, mesh):
    """Build the zeroed slot-major cache, sharded per the decode plan."""
    structs, specs = cache_specs(plan)
    shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                             is_leaf=lambda x: isinstance(x, P))

    def init():
        return jax.tree_util.tree_map_with_path(
            _init_leaf, structs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    return jax.jit(init, out_shardings=shardings)


def make_insert_fn(plan: CellPlan, plan_pre: CellPlan, mesh):
    """insert(cache, pre_cache, slot) -> cache (donated, in place).

    ``pre_cache`` is the B=1 cache returned by the engine prefill step
    (seq length ``plan_pre.cell.seq_len``); ``slot`` a replicated int32.
    """
    assert plan.cp == (plan.tp,) and plan_pre.cp == (plan_pre.tp,), (
        "engine admit requires tp-only context parallelism on both the "
        "prefill and decode plans")
    _, cspecs = cache_specs(plan)
    _, pspecs = cache_specs(plan_pre)
    num_slots = plan.cell.global_batch
    dp_size = plan.dp_size if plan.batch_sharded else 1
    slots_loc = num_slots // dp_size
    S_pre = plan_pre.cell.seq_len
    S_max = plan.cell.seq_len
    tp = plan.tp

    def ins(cache, pre, slot):
        if dp_size > 1:
            r_dp = jnp.zeros((), jnp.int32)
            for a in plan.dp:
                r_dp = r_dp * lax.axis_size(a) + lax.axis_index(a)
        else:
            r_dp = jnp.zeros((), jnp.int32)
        own = (slot >= r_dp * slots_loc) & (slot < (r_dp + 1) * slots_loc)
        ls = jnp.clip(slot - r_dp * slots_loc, 0, slots_loc - 1)

        def merge(path, c, p):
            p0 = p[:, 0]                              # drop the B=1 dim
            cur = lax.dynamic_index_in_dim(c, ls, axis=1, keepdims=False)
            if _is_kv_path(path) and S_pre != S_max:
                # prefill KV is seq-sharded at S_pre granularity; gather
                # the single request's KV and re-slice at S_max granularity
                full = lax.all_gather(p0, tp, axis=1, tiled=True)
                Ls = c.shape[2]
                gpos = lax.axis_index(tp) * Ls + jnp.arange(Ls)
                src = jnp.take(full, jnp.minimum(gpos, S_pre - 1), axis=1)
                valid = (gpos < S_pre)[None, :, None, None]
                row = jnp.where(own & valid, src.astype(c.dtype), cur)
            else:
                row = jnp.where(own, p0.astype(c.dtype), cur)
            return c.at[:, ls].set(row)

        return jax.tree_util.tree_map_with_path(merge, cache, pre)

    fn = jax.shard_map(ins, mesh=mesh, in_specs=(cspecs, pspecs, P()),
                       out_specs=cspecs, check_vma=False)
    return jax.jit(fn, donate_argnums=(0,))


class PagedKVCache:
    """Slot-major device cache + host-side slot/page allocator."""

    def __init__(self, plan: CellPlan, plan_pre: CellPlan, mesh,
                 page_size: int = 64):
        self.plan = plan
        self.allocator = SlotAllocator(plan.cell.global_batch,
                                       plan.cell.seq_len, page_size)
        self.buffers = make_init_fn(plan, mesh)()
        self._insert = make_insert_fn(plan, plan_pre, mesh)

    def admit(self, pre_cache, seq_len: int) -> int:
        """Allocate a slot and splice a prefilled cache into it."""
        slot = self.allocator.alloc(seq_len)
        self.buffers = self._insert(self.buffers, pre_cache,
                                    jnp.asarray(slot, jnp.int32))
        return slot

    def evict(self, slot: int):
        self.allocator.free(slot)

    def rollback(self, slot: int, new_len: int):
        """Position-range rollback after rejected speculative drafts.

        Returns the occupancy (page accounting) of cache positions
        ``new_len..`` to the allocator.  The device-side KV rows for the
        rejected range are left in place deliberately: they sit strictly
        beyond the slot's committed position, so the per-position causal
        mask keeps every future query from attending to them, and the
        next verify window (which starts exactly at ``new_len``)
        overwrites them before they could ever become visible.
        """
        self.allocator.rollback(slot, new_len)

    def bytes_per_slot(self) -> int:
        per = 0
        for leaf in jax.tree.leaves(self.buffers):
            per += leaf.nbytes // leaf.shape[1]
        return per
