"""Pooled KV page cache + slot-major state cache for the serving engine.

Device layout is a true block-table design: attention KV lives in ONE
shared page pool ``[U, num_pages, page_size, Hkv, dh]`` whose page dim
is sharded over all mesh axes (dp x tp), and each request slot maps an
ordered list of pages through a per-slot block-table row
``[pages_per_slot]`` of global page ids (-1 = unmapped).  Decode/verify
attention gathers K/V through that table (``cache[page, offset]``), so
a slot's HBM footprint is ``ceil(len / page_size)`` pages — NOT a dense
``max_seq`` reservation — and ``num_pages`` caps concurrent context,
independent of the slot count.  Recurrent/SSM state (mamba / xLSTM /
RWKV) stays slot-major ``[U, slots, ...]``: it is O(1) per slot and
every block reads all of it every step, so paging buys it nothing.
Buffers are allocated once at engine start and donated through every
step — steady-state serving is still allocation-free.

The host side is a ``SlotAllocator``: a free-list of request slots plus
a REAL page allocator — global free list (partitioned into one region
per dp group, because slots are batch-sharded over dp and a slot's
pages must live on its own dp group's tp shards), per-slot page lists,
alloc-on-extend (``ensure``), and page-exact ``rollback``/``free`` that
return the tail's pages to the pool.  Exhaustion is typed:
``SlotsExhausted`` vs ``PagePoolExhausted`` (see ``serving.errors``).
Reclamation under pressure is the engine's job, built on this
allocator's primitives: pool-pressure preemption (``free`` the victim,
re-admit later) and replica-loss/suspend paths all return pages through
the same ``free``/limbo machinery, so a fault can never leak a page.

Deferred-free epochs (async serving): when the engine pipelines decode
steps (``EngineConfig.async_depth > 0``) it dispatches step t+1 before
it has synced step t's tokens, so a block-table snapshot for an
in-flight step may still name pages the host has since decided to free
(late EOS retirement, speculative rollback).  ``note_dispatch()`` /
``note_commit()`` bracket every device step; while any dispatched step
is uncommitted, freed pages park on a limbo list tagged with the
newest dispatch epoch and only rejoin the free pool once every step
whose snapshot could name them has committed.  A limbo page can never
be remapped to a new slot, so an in-flight step's reads and writes
always land in pages still owned by the slot its snapshot mapped them
to.  With no step in flight (the synchronous engine), frees are
immediate and behavior is byte-identical to the pre-async allocator.

``insert`` splices a freshly prefilled single-request cache into the
pool: state leaves are a slot-row write; KV leaves all_gather the one
request's seq-sharded prefill KV over tp (the natural admit cost) and
scatter it page-block-wise into the slot's freshly mapped pages —
out-of-shard / unmapped targets drop, so only ``ceil(prompt_len /
page_size)`` pages are ever touched.

Safety invariant (why stale pool rows can never leak between slots): a
slot's visible positions ``[0, len)`` are always positions the slot
itself wrote — prefill fills its pages at admit, decode/verify writes
run contiguously upward from there, and pages are only mapped/unmapped
at the tail — while every read masks entries beyond the slot's own
positions, so a recycled page's previous contents are overwritten
before they could ever score.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.boundary import (BoundaryCodec, coded_kv_migrate,
                             kv_wire_bytes, kv_wire_roundtrip)
from ..launch.specs import (CellPlan, cache_specs, default_num_pages,
                            migrate_stage_shape, paged_cache_specs,
                            pages_per_slot)
from ..models.context import axes_linear_index, pool_local_pages
from .errors import CacheOverflowError, PagePoolExhausted, SlotsExhausted

_KV_KEYS = ("kv", "cross_kv")


class SlotAllocator:
    """Free-list slot allocation + a real shared-pool page allocator.

    ``num_pages`` defaults to ``num_slots * pages_per_slot`` (the dense
    reservation — can never exhaust before the slots do); sizing it
    smaller is the paging payoff: slots share the pool and long-context
    slots no longer reserve ``max_seq`` up front.  ``num_groups`` > 1
    partitions the pool into equal contiguous regions and pins each
    slot to the region of its dp group (``slot // slots_per_group``),
    matching the device-side page sharding over dp x tp.

    Compacted per-shard page lists: with ``shards_per_group`` > 1 each
    group's region further splits into one contiguous range per tp
    shard (``pages_local`` pages each — the device-side pool slice),
    and alongside the block table the allocator maintains
    ``page_list_loc`` / ``page_list_pos``: ``[num_slots,
    shards_per_group, pages_per_shard]`` int32 arrays naming, for each
    (slot, shard), the shard-LOCAL pool rows of the slot's resident
    pages and the absolute position of each page's first token
    (ordinal * page_size); -1 = no page.  The fused paged-decode
    kernel walks these lists instead of the full block table, so every
    page a slot maps must land within ``pages_per_shard =
    ceil(pages_per_slot / shards_per_group)`` rows on its shard —
    ``_map_pages`` balances placement to keep that invariant (fewest
    of the slot's pages first).  The cost of the static per-shard
    width is a mild admission tightening: free pages clustered on one
    shard beyond ``pages_per_shard`` are unusable by a single slot, so
    capacity checks count ``min(free_on_shard, headroom_on_shard)``
    per shard rather than the group total.  An overflowing page would
    be invisible to the fused kernel (silently unattended positions),
    so the invariant is enforced at allocation, never best-effort.
    ``shards_per_group=1`` (the default) keeps one list per group and
    is behavior-identical to the pre-compaction allocator.
    """

    def __init__(self, num_slots: int, max_seq: int, page_size: int = 64,
                 num_pages: int | None = None, num_groups: int = 1,
                 shards_per_group: int = 1):
        if num_slots <= 0 or page_size <= 0 or max_seq <= 0:
            raise ValueError((num_slots, max_seq, page_size))
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot(max_seq, page_size)
        if num_pages is None:
            num_pages = num_slots * self.pages_per_slot
        if num_pages <= 0 or num_pages % num_groups != 0 \
                or num_slots % num_groups != 0:
            raise ValueError(
                f"num_pages={num_pages} / num_slots={num_slots} must be "
                f"positive multiples of num_groups={num_groups}")
        self.num_pages = num_pages
        self.num_groups = num_groups
        self.pages_per_group = num_pages // num_groups
        if shards_per_group <= 0 \
                or self.pages_per_group % shards_per_group != 0:
            raise ValueError(
                f"pages_per_group={self.pages_per_group} must be a "
                f"positive multiple of shards_per_group={shards_per_group}")
        self.shards_per_group = shards_per_group
        #: pages of one (group, shard) range — the device pool slice size
        self.pages_local = self.pages_per_group // shards_per_group
        #: static width of one (slot, shard) compacted page list
        self.pages_per_shard = -(-self.pages_per_slot // shards_per_group)
        self._slots_per_group = num_slots // num_groups
        self._free = deque(range(num_slots))
        self._free_pages = [
            [deque(range(g * self.pages_per_group + s * self.pages_local,
                         g * self.pages_per_group
                         + (s + 1) * self.pages_local))
             for s in range(shards_per_group)]
            for g in range(num_groups)]
        self._len = np.zeros(num_slots, np.int64)   # current seq occupancy
        self._pages: list[list[int]] = [[] for _ in range(num_slots)]
        #: pages each slot holds on each shard (compacted-list fill level)
        self._shard_count = np.zeros((num_slots, shards_per_group),
                                     np.int32)
        # deferred-free epoch state: device steps launched vs joined, and
        # pages freed while a snapshot may still name them —
        # (release_epoch, page) pairs, nondecreasing in epoch
        self._dispatched = 0
        self._committed = 0
        self._limbo: deque[tuple[int, int]] = deque()
        #: [num_slots, pages_per_slot] int32 global page ids, -1 unmapped —
        #: passed verbatim as the device block table every step
        self.block_table = np.full((num_slots, self.pages_per_slot), -1,
                                   np.int32)
        #: [num_slots, shards_per_group, pages_per_shard] int32 — the
        #: compacted per-shard page lists the fused decode kernel walks:
        #: shard-local pool row of each resident page (-1 = none), and
        #: the absolute position of the page's first token.  Staged to
        #: device per dispatch exactly like the block table.
        self.page_list_loc = np.full(
            (num_slots, shards_per_group, self.pages_per_shard), -1,
            np.int32)
        self.page_list_pos = np.full(
            (num_slots, shards_per_group, self.pages_per_shard), -1,
            np.int32)

    # -- sizing / introspection -------------------------------------------

    def group_of(self, slot: int) -> int:
        return slot // self._slots_per_group

    def _shard_of(self, page: int) -> int:
        """tp-shard index (within its group) holding global ``page``."""
        return (page // self.pages_local) % self.shards_per_group

    @property
    def num_free(self) -> int:
        return len(self._free)

    def free_pages_in_group(self, group: int) -> int:
        return sum(len(d) for d in self._free_pages[group])

    def limbo_pages_in_group(self, group: int) -> int:
        """Pages of ``group`` parked in deferred-free limbo (freed, but an
        uncommitted device step's snapshot may still name them)."""
        lo = group * self.pages_per_group
        hi = lo + self.pages_per_group
        return sum(1 for _, p in self._limbo if lo <= p < hi)

    def _limbo_by_shard(self, group: int) -> list:
        """Limbo page count per tp shard of ``group`` — what each shard's
        free deque gets back once the pipeline drains."""
        counts = [0] * self.shards_per_group
        lo = group * self.pages_per_group
        hi = lo + self.pages_per_group
        for _, p in self._limbo:
            if lo <= p < hi:
                counts[self._shard_of(p)] += 1
        return counts

    def _fresh_capacity(self, group: int) -> int:
        """Pages a FRESH slot of ``group`` could map right now: per-shard
        free pages, capped at the compacted-list width per shard."""
        return sum(min(len(d), self.pages_per_shard)
                   for d in self._free_pages[group])

    def _admit_capacity(self, group: int, after_flush: bool = False) -> int:
        """Pages ADMISSION may count on for a fresh slot of ``group``.

        Unlike ``_fresh_capacity`` (the mechanism ``alloc`` enforces),
        this is admission POLICY and it is limbo-aware: pages parked in
        deferred-free limbo are claims the pool already owes to slots
        that will grow — admitting against them lets a request in whose
        first alloc-on-extend then starves the group mid-flight and
        triggers needless preemption churn.  Limbo pages count AGAINST
        the free list here, so a dry-pool-plus-limbo group reports 0.
        With ``after_flush=True`` the same capacity is computed as if
        the pipeline had drained (limbo pages rejoined their shards'
        free deques) — the engine uses it to decide whether a
        flush-then-retry would unblock the queue head.
        """
        limbo = self._limbo_by_shard(group)
        if after_flush:
            return sum(min(len(d) + limbo[s], self.pages_per_shard)
                       for s, d in enumerate(self._free_pages[group]))
        return max(0, self._fresh_capacity(group) - sum(limbo))

    def _slot_capacity(self, slot: int) -> int:
        """Additional pages ``slot`` could map right now (per-shard free
        pages capped at the slot's remaining compacted-list headroom)."""
        free = self._free_pages[self.group_of(slot)]
        cnt = self._shard_count[slot]
        return sum(min(len(free[s]), self.pages_per_shard - int(cnt[s]))
                   for s in range(self.shards_per_group))

    def pages_needed(self, seq_len: int) -> int:
        return -(-seq_len // self.page_size)

    def pages_used(self, slot: int) -> int:
        return len(self._pages[slot])

    @property
    def total_pages(self) -> int:
        return self.num_pages

    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self._pages)

    @property
    def pages_in_limbo(self) -> int:
        """Pages freed but not yet safe to remap (an uncommitted device
        step's block-table snapshot may still name them)."""
        return len(self._limbo)

    @property
    def pressure(self) -> float:
        """Fraction of the pool unavailable for new mappings (mapped or
        parked in limbo).  1.0 means the next alloc-on-extend in a dry
        group triggers the engine's pool-pressure preemption path (or
        a typed ``PagePoolExhausted`` with ``preempt=False``) — the
        per-step signal ``repro.serving.slo.SLOMonitor`` trends."""
        return (self.pages_in_use + self.pages_in_limbo) / self.num_pages

    # -- deferred-free epochs (async dispatch/commit) ----------------------

    def note_dispatch(self):
        """A device step was launched against the CURRENT block table.

        Until the matching ``note_commit``, any page freed (evict,
        rollback) parks on the limbo list instead of the free pool: the
        in-flight step's snapshot may still read or write it, and
        handing it to a new slot would let two owners race on one page.
        """
        self._dispatched += 1

    def note_commit(self):
        """The OLDEST in-flight device step joined the host (its output
        was synced, so its reads/writes have fully executed).  Limbo
        pages whose every possible holder has now committed rejoin their
        group's free pool."""
        if self._committed >= self._dispatched:
            raise ValueError("note_commit without a matching "
                             "note_dispatch: no device step is in flight")
        self._committed += 1
        while self._limbo and self._limbo[0][0] <= self._committed:
            _, page = self._limbo.popleft()
            g = page // self.pages_per_group
            self._free_pages[g][self._shard_of(page)].append(page)

    def _release_page(self, page: int):
        if self._dispatched > self._committed:
            # unsafe until every step dispatched so far has committed:
            # tag with the newest epoch that could hold a snapshot
            self._limbo.append((self._dispatched, page))
        else:
            g = page // self.pages_per_group
            self._free_pages[g][self._shard_of(page)].append(page)

    # -- page mapping (internal) ------------------------------------------

    def _map_pages(self, slot: int, n: int):
        g = self.group_of(slot)
        if n > self._slot_capacity(slot):
            free = self.free_pages_in_group(g)
            raise PagePoolExhausted(
                f"slot {slot} (group {g}) needs {n} page(s); capacity "
                f"{self._slot_capacity(slot)} ({free} free of "
                f"{self.pages_per_group} in its group, per-shard "
                f"compacted-list width {self.pages_per_shard}; "
                f"{self.pages_in_use}/{self.num_pages} mapped pool-wide)")
        free = self._free_pages[g]
        cnt = self._shard_count[slot]
        for _ in range(n):
            # balanced placement: the shard where this slot holds the
            # fewest pages (so no shard's compacted list overflows its
            # static width), tie-broken toward the shard with the most
            # free pages (global balance), then lowest index (determinism)
            s = min((s for s in range(self.shards_per_group)
                     if free[s] and cnt[s] < self.pages_per_shard),
                    key=lambda s: (int(cnt[s]), -len(free[s]), s))
            page = free[s].popleft()
            ordinal = len(self._pages[slot])
            self.block_table[slot, ordinal] = page
            self.page_list_loc[slot, s, cnt[s]] = page % self.pages_local
            self.page_list_pos[slot, s, cnt[s]] = ordinal * self.page_size
            cnt[s] += 1
            self._pages[slot].append(page)

    def _unmap_tail(self, slot: int, keep: int):
        cnt = self._shard_count[slot]
        while len(self._pages[slot]) > keep:
            page = self._pages[slot].pop()
            self.block_table[slot, len(self._pages[slot])] = -1
            # the popped page has the slot's highest ordinal, and each
            # per-shard list is ordinal-ordered, so it is the LAST live
            # entry of its own shard's compacted list
            s = self._shard_of(page)
            cnt[s] -= 1
            self.page_list_loc[slot, s, cnt[s]] = -1
            self.page_list_pos[slot, s, cnt[s]] = -1
            self._release_page(page)

    # -- slot lifecycle ----------------------------------------------------

    def can_admit(self, seq_len: int, after_flush: bool = False,
                  groups=None) -> bool:
        """True iff some free slot's group can map ``seq_len`` tokens.

        Limbo-aware (see ``_admit_capacity``): pages parked in
        deferred-free limbo never count toward admission, so a dry pool
        with parked pages rejects instead of admitting a request that
        would starve mid-flight.  ``after_flush=True`` answers the
        counterfactual "would this admit pass once the pipeline drains
        and limbo pages rejoin the pool?" — the engine's
        flush-then-retry gate.  ``groups`` (optional iterable) restricts
        the candidate free slots to those dp groups — the disaggregated
        engine admits prefills into prefill-role groups only.
        """
        if not 0 < seq_len <= self.max_seq:
            return False
        need = self.pages_needed(seq_len)
        cand = set(groups) if groups is not None else None
        return any(need <= self._admit_capacity(self.group_of(s),
                                                after_flush=after_flush)
                   for s in self._free
                   if cand is None or self.group_of(s) in cand)

    def alloc(self, seq_len: int, groups=None) -> int:
        """Claim a slot + map pages for ``seq_len`` already-held tokens.

        Picks the first free slot (FIFO) whose group has enough free
        pages; ``groups`` (optional iterable) restricts candidates to
        those dp groups (disaggregated admission targets prefill-role
        groups).  Typed failures: ``SlotsExhausted`` when no slot is
        free, ``PagePoolExhausted`` when slots are free but no group can
        map the request — the caller queues in either case.  Deliberately
        limbo-PERMISSIVE (mechanism, not policy): free-list pages are
        usable the instant they are free — admission policy
        (``can_admit``) is where limbo pressure gates new work.
        """
        if not 0 < seq_len <= self.max_seq:
            raise ValueError(f"seq_len {seq_len} not in (0, {self.max_seq}]")
        cand = set(groups) if groups is not None else None
        free = [s for s in self._free
                if cand is None or self.group_of(s) in cand]
        if not free:
            raise SlotsExhausted(
                f"all {self.num_slots} slots in use"
                + ("" if cand is None else f" (groups {sorted(cand)})"))
        need = self.pages_needed(seq_len)
        for slot in free:
            if need <= self._fresh_capacity(self.group_of(slot)):
                break
        else:
            raise PagePoolExhausted(
                f"{need} page(s) for seq_len {seq_len}: no free slot's "
                f"group has them ({self.pages_in_use}/{self.num_pages} "
                "mapped)")
        self._free.remove(slot)
        self._map_pages(slot, need)
        self._len[slot] = seq_len
        return slot

    def ensure(self, slot: int, new_len: int):
        """Alloc-on-extend: grow ``slot``'s mapping to cover ``new_len``
        positions (no-op if already covered).  The engine calls this
        BEFORE launching a decode/verify step so every position the step
        writes has a mapped page.  Raises ``CacheOverflowError`` past
        ``max_seq`` (the old silent clamp hid scheduler bugs) and
        ``PagePoolExhausted`` when the slot's group has no page left.
        """
        if self._len[slot] <= 0:
            raise ValueError(f"ensure on free slot {slot}")
        if new_len > self.max_seq:
            raise CacheOverflowError(
                f"slot {slot}: {new_len} positions > max_seq "
                f"{self.max_seq}")
        self._map_pages(slot,
                        self.pages_needed(new_len) - self.pages_used(slot))
        self._len[slot] = max(self._len[slot], new_len)

    def extend(self, slot: int, n: int = 1):
        self.ensure(slot, int(self._len[slot]) + n)

    def rollback(self, slot: int, new_len: int):
        """Roll a slot's occupancy back to ``new_len`` positions,
        returning the rejected tail's pages to the pool (page-exact).

        Speculative decoding maps+writes KV for every draft position
        before acceptance is known; the scheduler calls this to shrink
        to the committed length.  Only shrinking (or no-op) is legal —
        growth goes through ``ensure``/``extend``.
        """
        if not 0 < new_len <= self._len[slot]:
            raise ValueError(
                f"rollback slot {slot} to {new_len}: occupancy is "
                f"{int(self._len[slot])} (must shrink to a positive length)")
        self._unmap_tail(slot, self.pages_needed(new_len))
        self._len[slot] = new_len

    def free(self, slot: int):
        if self._len[slot] <= 0:
            # typed (not assert): a double free surviving `python -O`
            # would put the slot on the free list twice and hand it to
            # two requests at once
            raise ValueError(f"slot {slot} already free")
        self._unmap_tail(slot, 0)
        self._len[slot] = 0
        self._free.append(slot)

    # -- cross-group migration (disaggregated prefill/decode) --------------

    def pages_in_use_by_group(self, group: int) -> int:
        lo = group * self._slots_per_group
        return sum(len(self._pages[s])
                   for s in range(lo, lo + self._slots_per_group))

    def free_slot_in_group(self, group: int) -> int | None:
        """First free slot of ``group`` (FIFO), or None."""
        for s in self._free:
            if self.group_of(s) == group:
                return s
        return None

    def placement_counts(self, group: int, need: int) -> list | None:
        """Per-shard page counts balanced placement WOULD give a fresh
        slot of ``group`` mapping ``need`` pages right now, or None if
        the group cannot map them.  Pure simulation (no mutation) — the
        disaggregated router uses it to predict, before a prefill runs,
        whether a decode group could mirror the resulting placement.
        """
        avail = [len(d) for d in self._free_pages[group]]
        cnt = [0] * self.shards_per_group
        for _ in range(need):
            cands = [s for s in range(self.shards_per_group)
                     if avail[s] and cnt[s] < self.pages_per_shard]
            if not cands:
                return None
            s = min(cands, key=lambda s: (cnt[s], -avail[s], s))
            avail[s] -= 1
            cnt[s] += 1
        return cnt

    def peek_alloc(self, seq_len: int, groups=None) -> int | None:
        """The slot ``alloc(seq_len, groups)`` would claim RIGHT NOW (no
        mutation), or None if it would raise.  The disaggregated router
        runs its whole admission pre-check — prefill-group capacity,
        placement simulation, decode-group mirror capacity — against
        this prediction before popping the queue head, so an admission
        that starts can always finish."""
        if not 0 < seq_len <= self.max_seq:
            return None
        cand = set(groups) if groups is not None else None
        need = self.pages_needed(seq_len)
        for s in self._free:
            if cand is not None and self.group_of(s) not in cand:
                continue
            if need <= self._fresh_capacity(self.group_of(s)):
                return s
        return None

    def can_place_mirror(self, dst_group: int, counts) -> bool:
        """True iff ``dst_group`` has a free slot and each tp shard s can
        supply ``counts[s]`` pages from its free deque — the mirror
        feasibility test against a SIMULATED source placement
        (``placement_counts``), used before the source pages even
        exist."""
        if self.free_slot_in_group(dst_group) is None:
            return False
        free = self._free_pages[dst_group]
        return all(int(c) <= len(free[s]) for s, c in enumerate(counts))

    def can_migrate(self, src_slot: int, dst_group: int) -> bool:
        """True iff ``dst_group`` has a free slot AND every tp shard can
        mirror ``src_slot``'s per-shard page counts from its own free
        deque.  Mirroring is stricter than balanced placement — the
        device migration is ONE ppermute in which shard s of the source
        group sends its pages straight to shard s of the destination —
        so a group passing ``can_admit`` may still refuse a migration;
        the router treats that as starvation and keeps the request
        queued (or falls back to another decode group).
        """
        if self._len[src_slot] <= 0 or dst_group == self.group_of(src_slot):
            return False
        if self.free_slot_in_group(dst_group) is None:
            return False
        cnt = self._shard_count[src_slot]
        free = self._free_pages[dst_group]
        return all(int(cnt[s]) <= len(free[s])
                   for s in range(self.shards_per_group))

    def migrate_slot(self, src_slot: int, dst_group: int) -> int:
        """Move ``src_slot``'s mapping to a fresh slot of ``dst_group``
        with SHARD-MIRRORED placement; returns the new slot id.

        For each source page held on tp shard s (in compacted-list
        order), a destination page is popped from ``dst_group``'s
        shard-s free deque and placed at the SAME list position with the
        SAME position offset — so the device-side handoff is a single
        ``ppermute`` over the dp axis (shard s talks only to shard s)
        and the destination compacted lists/block table describe the
        received pages without any re-indexing.  The source slot is then
        freed through the ordinary ``free``/limbo machinery: with steps
        in flight its pages park in deferred-free limbo, so a migration
        can never hand a page to a new owner while an uncommitted
        snapshot still names it.  Raises ``SlotsExhausted`` /
        ``PagePoolExhausted`` (typed) when ``dst_group`` cannot take the
        slot — callers should gate on ``can_migrate``.
        """
        if self._len[src_slot] <= 0:
            raise ValueError(f"migrate_slot: slot {src_slot} is free")
        src_group = self.group_of(src_slot)
        if dst_group == src_group or not 0 <= dst_group < self.num_groups:
            raise ValueError(
                f"migrate_slot: dst_group {dst_group} invalid for slot "
                f"{src_slot} of group {src_group}")
        dst_slot = self.free_slot_in_group(dst_group)
        if dst_slot is None:
            raise SlotsExhausted(f"no free slot in group {dst_group}")
        cnt = self._shard_count[src_slot]
        free = self._free_pages[dst_group]
        for s in range(self.shards_per_group):
            if int(cnt[s]) > len(free[s]):
                raise PagePoolExhausted(
                    f"migrate slot {src_slot} -> group {dst_group}: shard "
                    f"{s} must mirror {int(cnt[s])} page(s) but has "
                    f"{len(free[s])} free")
        self._free.remove(dst_slot)
        pages_by_ordinal = {}
        for s in range(self.shards_per_group):
            for j in range(int(cnt[s])):
                page = free[s].popleft()
                self.page_list_loc[dst_slot, s, j] = page % self.pages_local
                pos = int(self.page_list_pos[src_slot, s, j])
                self.page_list_pos[dst_slot, s, j] = pos
                ordinal = pos // self.page_size
                self.block_table[dst_slot, ordinal] = page
                pages_by_ordinal[ordinal] = page
        self._pages[dst_slot] = [pages_by_ordinal[o]
                                 for o in sorted(pages_by_ordinal)]
        self._shard_count[dst_slot] = cnt
        self._len[dst_slot] = self._len[src_slot]
        self.free(src_slot)
        return dst_slot


def _is_kv_path(path) -> bool:
    return any(getattr(p, "key", None) in _KV_KEYS for p in path)


def _init_leaf(path, s):
    # rwkv's log-space max-tracker must start at -inf, everything else 0
    if any(getattr(p, "key", None) == "pp" for p in path):
        return jnp.full(s.shape, -1e30, s.dtype)
    return jnp.zeros(s.shape, s.dtype)


def make_init_fn(plan: CellPlan, mesh, page_size: int, num_pages: int):
    """Build the zeroed pool+state cache, sharded per the decode plan."""
    structs, specs = paged_cache_specs(plan, page_size, num_pages)
    shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                             is_leaf=lambda x: isinstance(x, P))

    def init():
        return jax.tree_util.tree_map_with_path(
            _init_leaf, structs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    return jax.jit(init, out_shardings=shardings)


def make_insert_fn(plan: CellPlan, plan_pre: CellPlan, mesh,
                   page_size: int, num_pages: int, kv_wire: str = "fp"):
    """insert(cache, pre_cache, slot, pages) -> cache (donated, in place).

    ``pre_cache`` is the B=1 cache returned by the engine prefill step
    (seq length ``plan_pre.cell.seq_len``); ``slot`` a replicated int32;
    ``pages`` the slot's freshly mapped block-table row (replicated
    int32 [pages_per_slot], -1 for entries beyond the prompt).  State
    leaves are a slot-row write; KV leaves gather the request's prefill
    KV over tp and scatter it page-block-wise into the pool — only the
    mapped pages are written (unmapped / non-resident targets drop), so
    an admit touches O(prompt_len), not O(max_seq), pool bytes.

    ``kv_wire="coded"`` roundtrips the inserted KV through the pow2
    int8 wire (``boundary.kv_wire_roundtrip``) so the pool holds
    wire-representable values: a later coded migration then re-encodes
    them bit-exactly (idempotence), which is what keeps disaggregated
    and colocated greedy streams identical under a lossy KV wire.
    Applied in EVERY topology when selected — colocated engines pay the
    same (one-time, per-admit) quantization as disaggregated ones.
    """
    assert plan.cp == (plan.tp,) and plan_pre.cp == (plan_pre.tp,), (
        "engine admit requires tp-only context parallelism on both the "
        "prefill and decode plans")
    _, cspecs = paged_cache_specs(plan, page_size, num_pages)
    _, pspecs = cache_specs(plan_pre)
    num_slots = plan.cell.global_batch
    dp_size = plan.dp_size if plan.batch_sharded else 1
    slots_loc = num_slots // dp_size
    S_pre = plan_pre.cell.seq_len
    tp = plan.tp
    pool_axes = tuple(plan.dp) + (plan.tp,)
    psz = page_size

    def ins(cache, pre, slot, pages):
        pidx = axes_linear_index(pool_axes)        # pool shard index
        if dp_size > 1:
            r_dp = jnp.zeros((), jnp.int32)
            for a in plan.dp:
                r_dp = r_dp * lax.axis_size(a) + lax.axis_index(a)
        else:
            r_dp = jnp.zeros((), jnp.int32)
        own = (slot >= r_dp * slots_loc) & (slot < (r_dp + 1) * slots_loc)
        ls = jnp.clip(slot - r_dp * slots_loc, 0, slots_loc - 1)

        def merge(path, c, p):
            p0 = p[:, 0]                              # drop the B=1 dim
            if _is_kv_path(path):
                # c: pool shard [U, P_loc, psz, Hkv, dh]; gather the one
                # request's full prefill KV, re-slice it into page
                # blocks, scatter through the slot's fresh table row
                P_loc = c.shape[1]
                full = lax.all_gather(p0, tp, axis=1, tiled=True)
                pps = pages.shape[0]
                gpos = jnp.arange(pps * psz)
                src = jnp.take(full, jnp.minimum(gpos, S_pre - 1), axis=1)
                src = src.reshape(c.shape[0], pps, psz, *c.shape[3:])
                src = src.astype(c.dtype)
                if kv_wire == "coded":
                    src = kv_wire_roundtrip(src)
                loc, _ = pool_local_pages(pages, pidx, P_loc)
                return c.at[:, loc].set(src, mode="drop")
            cur = lax.dynamic_index_in_dim(c, ls, axis=1, keepdims=False)
            row = jnp.where(own, p0.astype(c.dtype), cur)
            return c.at[:, ls].set(row)

        return jax.tree_util.tree_map_with_path(merge, cache, pre)

    fn = jax.shard_map(ins, mesh=mesh, in_specs=(cspecs, pspecs, P(), P()),
                       out_specs=cspecs, check_vma=False)
    return jax.jit(fn, donate_argnums=(0,))


def make_migrate_fn(plan: CellPlan, mesh, page_size: int, num_pages: int,
                    src_group: int, dst_group: int, coded: bool):
    """migrate(cache, src_bt, dst_bt, src_slot, dst_slot) -> cache
    (donated): move one slot's paged KV + state rows across dp groups.

    Compiled once per (src_group, dst_group) pair — the ppermute perm is
    static.  Per KV leaf, each tp shard of the source group gathers its
    resident pages of the source block row into a static
    ``[U, pages_per_slot, page_size, Hkv, dh]`` staging slab
    (non-resident rows zeroed), sends it through ONE
    ``boundary.coded_kv_migrate`` over the dp axis (pow2-absmax int8
    wire + f32 scales when ``coded``, plain fp otherwise), and the
    destination group's same-index shard scatters the slab through the
    MIRRORED destination block row (``SlotAllocator.migrate_slot``
    guarantees ordinal j is resident on dst shard s iff it was on src
    shard s, so no cross-shard reshuffle is ever needed).  Non-resident
    / non-destination targets drop exactly as on the insert path.
    Recurrent/SSM state leaves ride a plain fp ppermute of the source
    slot row into the destination slot row — O(1) per slot, see
    ``coded_kv_migrate``'s coded-vs-fp contract.
    """
    _, cspecs = paged_cache_specs(plan, page_size, num_pages)
    num_slots = plan.cell.global_batch
    dp_size = plan.dp_size
    slots_loc = num_slots // dp_size
    pool_axes = tuple(plan.dp) + (plan.tp,)
    assert len(plan.dp) == 1, "disaggregated migration needs one dp axis"
    dp_axis = plan.dp[0]
    perm = [(src_group, dst_group)]
    codec = BoundaryCodec(mode="int8" if coded else "none")

    def mig(cache, src_bt, dst_bt, src_slot, dst_slot):
        pidx = axes_linear_index(pool_axes)
        r_dp = lax.axis_index(dp_axis)
        ls_src = jnp.clip(src_slot - src_group * slots_loc, 0,
                          slots_loc - 1)
        ls_dst = jnp.clip(dst_slot - dst_group * slots_loc, 0,
                          slots_loc - 1)

        def move(path, c):
            if _is_kv_path(path):
                P_loc = c.shape[1]
                loc_s, ok_s = pool_local_pages(src_bt, pidx, P_loc)
                stage = jnp.take(c, jnp.minimum(loc_s, P_loc - 1), axis=1)
                stage = jnp.where(
                    ok_s.reshape(1, -1, 1, 1, 1), stage,
                    jnp.zeros((), c.dtype))
                stage = coded_kv_migrate(stage, codec, dp_axis, perm)
                loc_d, _ = pool_local_pages(dst_bt, pidx, P_loc)
                return c.at[:, loc_d].set(stage.astype(c.dtype),
                                          mode="drop")
            row = lax.dynamic_index_in_dim(c, ls_src, axis=1,
                                           keepdims=False)
            row = lax.ppermute(row, dp_axis, perm)
            cur = lax.dynamic_index_in_dim(c, ls_dst, axis=1,
                                           keepdims=False)
            new = jnp.where(r_dp == dst_group, row.astype(c.dtype), cur)
            return c.at[:, ls_dst].set(new)

        return jax.tree_util.tree_map_with_path(move, cache)

    fn = jax.shard_map(mig, mesh=mesh,
                       in_specs=(cspecs, P(), P(), P(), P()),
                       out_specs=cspecs, check_vma=False)
    return jax.jit(fn, donate_argnums=(0,))


class PagedKVCache:
    """Shared device KV page pool + slot-major state + host allocator."""

    def __init__(self, plan: CellPlan, plan_pre: CellPlan, mesh,
                 page_size: int = 64, num_pages: int | None = None,
                 kv_wire: str = "fp"):
        self.plan = plan
        self.mesh = mesh
        self.page_size = page_size
        self.kv_wire = kv_wire
        self.num_pages = (default_num_pages(plan, page_size)
                          if num_pages is None else num_pages)
        groups = plan.dp_size if plan.batch_sharded else 1
        # pool shards per group: the page dim is sharded over dp x tp, so
        # each group's contiguous region spans this many device slices —
        # the compacted per-shard page lists are built against it
        shards = (plan.dp_size * plan.tp_size) // groups
        self.allocator = SlotAllocator(
            plan.cell.global_batch, plan.cell.seq_len, page_size,
            num_pages=self.num_pages, num_groups=groups,
            shards_per_group=shards)
        self.buffers = make_init_fn(plan, mesh, page_size, self.num_pages)()
        self._insert = make_insert_fn(plan, plan_pre, mesh, page_size,
                                      self.num_pages, kv_wire)
        #: exact-length prefill buckets: one compiled insert per prefill
        #: seq length (the gather/re-slice inside depends on S_pre)
        self._insert_fns = {plan_pre.cell.seq_len: self._insert}
        #: compiled cross-group migration programs, one per static
        #: (src_group, dst_group) ppermute pair
        self._migrate_fns: dict = {}
        self._mig_bytes: int | None = None
        self.peak_pages_in_use = 0

    def _note_peak(self):
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.allocator.pages_in_use)

    @property
    def block_table(self) -> np.ndarray:
        """Host block table [slots, pages_per_slot] int32, -1 unmapped."""
        return self.allocator.block_table

    @property
    def page_list_loc(self) -> np.ndarray:
        """Compacted per-shard page lists [slots, shards, pages_per_shard]
        int32: shard-local pool row of each resident page, -1 = none."""
        return self.allocator.page_list_loc

    @property
    def page_list_pos(self) -> np.ndarray:
        """Absolute position of each compacted-list page's first token
        [slots, shards, pages_per_shard] int32, -1 = no page."""
        return self.allocator.page_list_pos

    def insert_fn_for(self, plan_pre: CellPlan):
        """The insert program for ``plan_pre``'s prefill length, compiled
        lazily — exact-length prefill buckets for recurrent families
        share one cache keyed by ``S_pre``."""
        S = plan_pre.cell.seq_len
        if S not in self._insert_fns:
            self._insert_fns[S] = make_insert_fn(
                self.plan, plan_pre, self.mesh, self.page_size,
                self.num_pages, self.kv_wire)
        return self._insert_fns[S]

    def admit(self, pre_cache, seq_len: int, plan_pre: CellPlan = None,
              groups=None) -> int:
        """Allocate a slot, map ``ceil(seq_len/page_size)`` pages, and
        splice the prefilled cache into them.  ``plan_pre`` selects a
        non-default exact-length prefill bucket's insert program;
        ``groups`` restricts the slot to those dp groups (disaggregated
        admission lands prefills in prefill-role groups)."""
        slot = self.allocator.alloc(seq_len, groups=groups)
        self._note_peak()
        ins = (self._insert if plan_pre is None
               else self.insert_fn_for(plan_pre))
        self.buffers = ins(
            self.buffers, pre_cache, jnp.asarray(slot, jnp.int32),
            jnp.asarray(self.allocator.block_table[slot], jnp.int32))
        return slot

    def migrate(self, src_slot: int, dst_group: int) -> int:
        """Move ``src_slot`` to a fresh slot of ``dst_group``: mirror the
        page mapping on the host (``SlotAllocator.migrate_slot``), then
        launch the compiled one-ppermute device handoff.  The source
        block row is snapshotted BEFORE the host free so the device
        gather still sees it; the freed source pages go through the
        ordinary limbo machinery, so with steps in flight no new owner
        can touch them until every dispatched snapshot commits.  Returns
        the destination slot id."""
        alloc = self.allocator
        src_group = alloc.group_of(src_slot)
        src_bt = np.array(alloc.block_table[src_slot], np.int32)
        dst_slot = alloc.migrate_slot(src_slot, dst_group)
        key = (src_group, dst_group)
        if key not in self._migrate_fns:
            self._migrate_fns[key] = make_migrate_fn(
                self.plan, self.mesh, self.page_size, self.num_pages,
                src_group, dst_group, coded=self.kv_wire == "coded")
        self.buffers = self._migrate_fns[key](
            self.buffers, jnp.asarray(src_bt),
            jnp.asarray(alloc.block_table[dst_slot], jnp.int32),
            jnp.asarray(src_slot, jnp.int32),
            jnp.asarray(dst_slot, jnp.int32))
        return dst_slot

    def migrate_wire_bytes(self) -> int:
        """Wire bytes of ONE slot migration (shape-static per engine):
        the per-shard KV staging slabs across all tp shards — int8 +
        f32 scales when ``kv_wire="coded"``, dtype bytes otherwise —
        plus the fp state rows.  What ``SLOMonitor`` adds to the step
        trace and ``emio_cost_from_trace`` prices per handoff."""
        if self._mig_bytes is None:
            coded = self.kv_wire == "coded"
            shards = self.allocator.shards_per_group
            total = 0
            for path, leaf in jax.tree_util.tree_leaves_with_path(
                    self.buffers):
                if _is_kv_path(path):
                    shape = migrate_stage_shape(self.plan, self.page_size,
                                                leaf.shape)
                    total += shards * kv_wire_bytes(
                        shape, leaf.dtype.itemsize, coded)
                else:
                    total += leaf.nbytes // leaf.shape[1]
            self._mig_bytes = int(total)
        return self._mig_bytes

    def ensure(self, slot: int, new_len: int):
        """Map pages (alloc-on-extend) so positions < ``new_len`` are
        writable; called before every decode/verify step."""
        self.allocator.ensure(slot, new_len)
        self._note_peak()

    def evict(self, slot: int):
        """Retire a slot: all its pages return to the pool and its block
        table row zeroes to -1, so any in-flight write the retired slot
        shape still carries is dropped on device."""
        self.allocator.free(slot)

    def rollback(self, slot: int, new_len: int):
        """Page-exact rollback after rejected speculative drafts.

        Returns the pages beyond ``ceil(new_len/page_size)`` to the
        pool.  The device-side KV rows for the rejected range are left
        in place deliberately: rows in still-mapped pages sit strictly
        beyond the slot's committed position (masked until the next
        verify window overwrites them), and rows in unmapped pages are
        unreachable — the table row is -1, and a future owner of the
        recycled page overwrites every position before exposing it.
        """
        self.allocator.rollback(slot, new_len)

    # -- async dispatch/commit epochs --------------------------------------

    def note_dispatch(self):
        """A decode/verify step was launched against a snapshot of the
        current block table; frees defer until it commits."""
        self.allocator.note_dispatch()

    def note_commit(self):
        """The oldest in-flight step's output was synced: release limbo
        pages no uncommitted snapshot can name anymore."""
        self.allocator.note_commit()

    @property
    def pages_in_limbo(self) -> int:
        return self.allocator.pages_in_limbo

    # -- memory accounting -------------------------------------------------

    def kv_page_bytes(self) -> int:
        """Device bytes of ONE pool page summed over layers/units."""
        per = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.buffers):
            if _is_kv_path(path):
                per += leaf.nbytes // self.num_pages
        return per

    def kv_bytes_mapped(self) -> int:
        """KV bytes actually backing live slots right now."""
        return self.allocator.pages_in_use * self.kv_page_bytes()

    def kv_bytes_pool(self) -> int:
        """Total pool capacity in bytes (the new HBM budget knob)."""
        return self.num_pages * self.kv_page_bytes()

    def kv_bytes_dense_reservation(self) -> int:
        """What the old slot-major layout reserved: every slot charged
        ``pages_per_slot`` pages up front, idle or not."""
        return (self.allocator.num_slots * self.allocator.pages_per_slot
                * self.kv_page_bytes())

    def state_bytes_per_slot(self) -> int:
        """Slot-major (recurrent state) bytes per slot — unchanged by
        paging, reported so the pool numbers aren't mistaken for the
        whole cache."""
        per = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.buffers):
            if not _is_kv_path(path):
                per += leaf.nbytes // leaf.shape[1]
        return per
