"""Typed serving-engine error family.

Every failure mode the engine or its allocator can hit is a distinct
exception type (never a bare ``assert`` or ``RuntimeError``): asserts
vanish under ``python -O``, and callers — schedulers, admission
controllers, tests — need to tell "the configuration can never serve"
from "the pool is full right now" without string-matching messages.

Hierarchy:

  ValueError
    EngineConfigError   unserveable (mesh/shape/family) configuration
    CacheOverflowError  a slot asked to grow past ``max_seq``
  RuntimeError
    SchedulerStall      ``run`` hit ``max_steps`` with work in flight
    SlotsExhausted      no free request slot (admission backpressure)
    PagePoolExhausted   no free KV page in the slot's pool group

``SlotsExhausted`` means "queue the request"; ``PagePoolExhausted`` on
admission means the same, but raised from a mid-flight ``ensure`` it
means the operator sized ``num_pages`` below the workload's concurrent
context demand — the pool, not the slot count, is the binding limit.
With ``EngineConfig.preempt`` (the default) a mid-flight
``PagePoolExhausted`` is absorbed by graceful degradation — the engine
evicts + re-queues the youngest slot of the starving group and retries
(``engine.preemptions`` counts these) — and only escapes to the caller
when preemption could not possibly help: the starving group has a
single live slot, i.e. the pool cannot hold even one request's demand.
``preempt=False`` restores the raw typed error for schedulers that
implement their own policy.

Async serving (``EngineConfig.async_depth > 0``) shifts WHEN, not
WHETHER, these fire: pages freed by a retirement or rollback park in
the allocator's deferred-free limbo until every dispatched block-table
snapshot has committed, so under overlap an ``ensure``/admission can
hit ``PagePoolExhausted`` one step earlier than the synchronous
schedule would (the pages are coming back, just not yet safe), and an
``ensure`` may even be charged to a slot whose EOS the host has not
discovered yet.  On a pool sized for the workload neither occurs; on a
deliberately undersized pool the failure is the same typed error, at
most one pipelined step sooner.
"""
from __future__ import annotations


class EngineConfigError(ValueError):
    """Unserveable engine configuration (bad mesh/shape/family combo).

    Raised from ``ServingEngine.__init__`` instead of ``assert`` so the
    checks survive ``python -O``.
    """


class CacheOverflowError(ValueError):
    """A slot was asked to grow beyond ``max_seq`` cache positions.

    Replaces the old silent ``min(len + n, max_seq)`` clamp in
    ``SlotAllocator.extend``: a clamp hides scheduler bugs (the engine
    must retire a slot at ``max_seq``, never keep decoding into it).
    """


class SchedulerStall(RuntimeError):
    """``run`` exhausted ``max_steps`` with requests still in flight."""


class SlotsExhausted(RuntimeError):
    """No free request slot; the scheduler should queue the request."""


class PagePoolExhausted(RuntimeError):
    """No free KV page (in the requesting slot's pool group).

    Distinct from ``SlotsExhausted``: slots may be free while the page
    pool is not — that is exactly the regime block-table paging enables
    (``num_pages`` sized below ``num_slots * pages_per_slot``).
    """
