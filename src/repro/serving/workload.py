"""Trace-driven serving workloads: seeded, replayable request traces.

A serving benchmark is only as honest as its arrival process.  Uniform
back-to-back requests hide every queueing effect that matters in
production — TTFT blowups under bursts, pool-pressure preemption, queue
growth during on/off tenant storms — so this module generates *traces*:
timestamped ``Request`` streams drawn from a mix of request classes,
fully determined by a seed (same seed, same trace, bit-for-bit), that
``replay`` feeds into a ``ServingEngine`` on a logical or wall clock.

Building blocks
---------------
``RequestClass``
    One tenant/workload type: an arrival process (``poisson`` — memory-
    less gaps at ``rate`` req/s — or ``onoff`` — exponential on/off
    phases; arrivals only while on, which is what makes a trace bursty),
    a prompt-length distribution with an optional long-context tail
    (``tail_p``/``tail_len`` model the retrieval-augmented minority that
    dominates KV-pool pressure), a generation-length range, and a
    sampling temperature.
``make_trace``
    Merge the per-class arrival streams over a horizon into one
    time-sorted ``Trace``.  Request ids encode the class (``"t2/chat/7"``
    = trace seed namespace, class, per-class index) so per-tenant SLOs
    can be split out of one run.
``zoo_mix`` / ``PRESETS``
    Canned multi-tenant mixes whose shape statistics follow the
    ``repro.configs`` zoo families: short chat turns (qwen-0.5b-style
    interactive), mid-length completion (gemma2/granite), long-context
    retrieval tails (jamba-style hybrids are why the tail knob exists),
    and a bursty on/off batch tenant.  All lengths scale to the
    engine's ``prefill_len``/``gen`` budget at trace-build time.
``replay``
    Drive an engine through a trace: submit every request whose arrival
    time has passed, tick the engine, notify observers/injectors.  The
    default clock is *logical* (``steps_per_s`` scheduler ticks per
    trace second — deterministic, so fault-injection tests replay
    exactly); ``wall=True`` uses the host clock instead (what the
    benches report).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .engine import Request

__all__ = ["PRESETS", "RequestClass", "Trace", "TracedRequest",
           "make_trace", "preset_trace", "replay", "zoo_mix"]


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One tenant's traffic model (all randomness comes from the trace
    seed — a class is pure data and safely shared between traces)."""

    name: str
    rate: float                      # mean arrivals per second while on
    arrival: str = "poisson"         # "poisson" | "onoff"
    on_s: float = 1.0                # mean on-phase length (onoff only)
    off_s: float = 1.0               # mean off-phase length (onoff only)
    prompt_len: Tuple[int, int] = (4, 16)     # uniform [lo, hi]
    tail_p: float = 0.0              # long-context tail probability
    tail_len: Tuple[int, int] = (16, 16)      # tail prompt range
    gen_len: Tuple[int, int] = (4, 16)        # uniform [lo, hi]
    temperature: float = 0.0
    distinct_tokens: bool = False    # draw each prompt WITHOUT
    #                                  replacement: no token (hence no
    #                                  n-gram) ever repeats inside a
    #                                  prompt, so prompt-lookup drafting
    #                                  has nothing to match — the
    #                                  workload where a learned drafter
    #                                  must carry speculation alone

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"class {self.name}: rate must be > 0")
        if self.arrival not in ("poisson", "onoff"):
            raise ValueError(f"class {self.name}: arrival {self.arrival}")
        for lo, hi in (self.prompt_len, self.tail_len, self.gen_len):
            if not 0 < lo <= hi:
                raise ValueError(f"class {self.name}: bad range {(lo, hi)}")
        if not 0.0 <= self.tail_p <= 1.0:
            raise ValueError(f"class {self.name}: tail_p {self.tail_p}")


@dataclasses.dataclass(frozen=True)
class TracedRequest:
    """One arrival: when it lands and what it asks for."""

    t: float                         # arrival time (s from trace start)
    cls: str                         # originating RequestClass.name
    req: Request


@dataclasses.dataclass(frozen=True)
class Trace:
    """A time-sorted, seed-determined request stream."""

    requests: Tuple[TracedRequest, ...]
    horizon_s: float
    seed: int

    def __len__(self):
        return len(self.requests)

    def by_class(self) -> dict:
        out: dict = {}
        for tr in self.requests:
            out.setdefault(tr.cls, []).append(tr)
        return out


def _arrival_times(cls: RequestClass, horizon_s: float,
                   rng: np.random.RandomState) -> List[float]:
    """Arrival timestamps for one class over [0, horizon_s)."""
    times: List[float] = []
    if cls.arrival == "poisson":
        t = rng.exponential(1.0 / cls.rate)
        while t < horizon_s:
            times.append(t)
            t += rng.exponential(1.0 / cls.rate)
        return times
    # on/off: exponential phase lengths, arrivals only while on — the
    # burst arrives at `rate` even though the long-run average is
    # rate * on/(on+off)
    t, on = 0.0, rng.rand() < cls.on_s / (cls.on_s + cls.off_s)
    while t < horizon_s:
        phase = rng.exponential(cls.on_s if on else cls.off_s)
        end = min(t + phase, horizon_s)
        if on:
            a = t + rng.exponential(1.0 / cls.rate)
            while a < end:
                times.append(a)
                a += rng.exponential(1.0 / cls.rate)
        t, on = end, not on
    return times


def make_trace(classes: Sequence[RequestClass], horizon_s: float,
               seed: int = 0, vocab: int = 256,
               max_prompt_len: Optional[int] = None,
               max_gen: Optional[int] = None,
               fixed_prompt_len: Optional[int] = None) -> Trace:
    """Merge the classes' arrival streams into one replayable trace.

    ``max_prompt_len``/``max_gen`` clamp every drawn length to the
    engine's budget (``prefill_len`` / ``max_seq - prefill_len``);
    ``fixed_prompt_len`` forces every prompt to exactly that length —
    required when serving recurrent-state families, whose prompts must
    arrive at ``prefill_len`` tokens.  Each class draws from its own
    ``fold_in``-style derived seed, so adding a class never perturbs
    the other classes' streams.
    """
    if not classes:
        raise ValueError("make_trace: need at least one RequestClass")
    out: List[TracedRequest] = []
    for ci, cls in enumerate(classes):
        rng = np.random.RandomState((seed * 1000003 + ci) % (2 ** 31 - 1))
        for j, t in enumerate(_arrival_times(cls, horizon_s, rng)):
            if fixed_prompt_len is not None:
                plen = fixed_prompt_len
            else:
                lo, hi = cls.prompt_len
                if cls.tail_p > 0 and rng.rand() < cls.tail_p:
                    lo, hi = cls.tail_len
                plen = int(rng.randint(lo, hi + 1))
                if max_prompt_len is not None:
                    plen = max(1, min(plen, max_prompt_len))
            glo, ghi = cls.gen_len
            gen = int(rng.randint(glo, ghi + 1))
            if max_gen is not None:
                gen = max(1, min(gen, max_gen))
            if cls.distinct_tokens:
                plen = min(plen, vocab)
                prompt = [int(x) for x in rng.choice(vocab, plen,
                                                     replace=False)]
            else:
                prompt = [int(x) for x in rng.randint(0, vocab, plen)]
            out.append(TracedRequest(
                t=float(t), cls=cls.name,
                req=Request(rid=f"t{seed}/{cls.name}/{j}", prompt=prompt,
                            max_new_tokens=gen,
                            temperature=cls.temperature)))
    out.sort(key=lambda tr: (tr.t, tr.req.rid))
    return Trace(requests=tuple(out), horizon_s=horizon_s, seed=seed)


def zoo_mix(prefill_len: int = 16, max_gen: int = 16,
            load: float = 8.0) -> List[RequestClass]:
    """The default multi-tenant mix, shaped after the config-zoo
    families: interactive chat (short prompts, short decodes —
    qwen1.5-0.5b-style traffic), completion (mid prompts/decodes —
    gemma2/granite-class), retrieval (long-context tail — the jamba-
    style workload that stresses the KV pool), and a bursty on/off
    batch tenant.  ``load`` is the aggregate mean arrival rate (req/s)
    split across the tenants; lengths scale to the engine budget.
    """
    p = max(prefill_len, 2)
    g = max(max_gen, 2)
    return [
        RequestClass("chat", rate=0.4 * load,
                     prompt_len=(max(1, p // 8), max(2, p // 2)),
                     gen_len=(max(1, g // 4), max(2, g // 2))),
        RequestClass("completion", rate=0.3 * load,
                     prompt_len=(max(1, p // 4), max(2, 3 * p // 4)),
                     gen_len=(max(1, g // 2), g)),
        RequestClass("retrieval", rate=0.15 * load,
                     prompt_len=(max(1, p // 2), max(2, 3 * p // 4)),
                     tail_p=0.5, tail_len=(max(1, 7 * p // 8), p),
                     gen_len=(max(1, g // 4), max(2, g // 2))),
        RequestClass("batch", rate=0.15 * load, arrival="onoff",
                     on_s=0.5, off_s=2.0,
                     prompt_len=(max(1, p // 4), p),
                     gen_len=(max(1, g // 2), g)),
    ]


#: Named workload presets: name -> (classes builder, description).
PRESETS = {
    "steady": (lambda p, g, load: [
        RequestClass("steady", rate=load,
                     prompt_len=(max(1, p // 2), p),
                     gen_len=(max(1, g // 2), g))],
        "single-tenant memoryless Poisson arrivals"),
    "bursty": (lambda p, g, load: [
        RequestClass("burst", rate=2.0 * load, arrival="onoff",
                     on_s=0.4, off_s=1.6,
                     prompt_len=(max(1, p // 2), p),
                     gen_len=(max(1, g // 2), g))],
        "on/off storms at 2x the mean rate while on"),
    "longtail": (lambda p, g, load: [
        RequestClass("body", rate=0.8 * load,
                     prompt_len=(max(1, p // 8), max(2, p // 2)),
                     gen_len=(max(1, g // 2), g)),
        RequestClass("tail", rate=0.2 * load,
                     prompt_len=(max(1, p // 2), max(2, 3 * p // 4)),
                     tail_p=0.8, tail_len=(max(1, 7 * p // 8), p),
                     gen_len=(max(1, g // 4), max(2, g // 2)))],
        "short-prompt body plus a long-context tail minority"),
    "multitenant": (zoo_mix, "chat/completion/retrieval/batch zoo mix"),
    "lowmatch": (lambda p, g, load: [
        RequestClass("lowmatch", rate=load,
                     prompt_len=(max(1, p // 2), p),
                     gen_len=(max(1, g // 2), g),
                     distinct_tokens=True)],
        "non-repetitive prompts (distinct tokens): n-gram prompt-lookup "
        "drafting degrades to repeat-last, learned draft heads do not"),
}


def preset_trace(name: str, horizon_s: float, seed: int = 0,
                 prefill_len: int = 16, max_gen: int = 16,
                 load: float = 8.0, vocab: int = 256,
                 fixed_prompt_len: Optional[int] = None) -> Trace:
    """Build a named preset's trace scaled to the engine budget."""
    if name not in PRESETS:
        raise ValueError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
    builder, _ = PRESETS[name]
    return make_trace(builder(prefill_len, max_gen, load), horizon_s,
                      seed=seed, vocab=vocab, max_prompt_len=prefill_len,
                      max_gen=max_gen, fixed_prompt_len=fixed_prompt_len)


def replay(engine, trace: Trace, observers: Sequence = (),
           steps_per_s: float = 50.0, wall: bool = False,
           max_steps: int = 100000) -> dict:
    """Feed ``trace`` into ``engine`` and serve it to completion.

    Requests are submitted once their arrival time has passed on the
    replay clock — logical by default (tick ``i`` is trace time
    ``i / steps_per_s``; fully deterministic, the mode every identity
    test uses), or the host wall clock with ``wall=True``.  After every
    scheduler tick each observer's ``on_step(engine)`` runs (SLO
    monitors record, fault injectors strike).  Returns ``{rid: tokens}``
    for every request in the trace.

    Observers that mutate the engine (``FaultInjector``) re-queue work;
    the loop keeps ticking until the engine drains, so a fault landing
    on the very last tick still gets re-served.
    """
    from .errors import SchedulerStall
    for obs in observers:
        if obs not in engine.observers:
            engine.observers.append(obs)
    pending = list(trace.requests)
    results: dict = {}
    t0 = time.perf_counter()
    for tick in range(max_steps):
        now = (time.perf_counter() - t0) if wall else tick / steps_per_s
        while pending and pending[0].t <= now:
            engine.submit(pending.pop(0).req)
        for req, out in engine.step():
            results[req.rid] = out
        for obs in observers:
            on_step = getattr(obs, "on_step", None)
            if on_step is not None:
                on_step(engine)
        if not pending and engine.idle:
            break
    else:
        raise SchedulerStall(
            f"replay: {len(pending)} arrivals unsubmitted, "
            f"{engine.num_active} slots active after {max_steps} ticks")
    return results
