"""Deterministic self-drafting proposers for speculative decoding.

The engine supports two drafters (``EngineConfig.drafter``), split by
where the proposal is computed:

* ``"ngram"`` — the host-side prompt-lookup drafter in this module.
  No draft model, no extra device work: match the longest recent
  suffix of the slot's committed token history against earlier
  occurrences and propose the continuation that followed last time.
  On repetitive workloads (code, structured text, copy-heavy prompts)
  acceptance is high; on incompressible streams it degrades gracefully
  to vanilla decoding (the verify step still commits one token per
  step, exactly like spec_k=0).  The cost is structural, not
  per-token: the host must SEE step t's committed tokens before it
  can draft step t+1, so every verify dispatch is fenced by a device
  sync and ``async_depth`` can only overlap admission prefill.

* ``"heads"`` — learned draft heads (``models.draft_heads``, trained
  Medusa-style against the next-k-token objective) evaluated inside
  the verify step itself.  Acceptance, the correction token and the
  NEXT step's drafts are all computed on device from the verify
  logits and the trunk's final hidden, so the next verify feed chains
  device-to-device and verify dispatches pipeline under
  ``async_depth > 0`` with no host join between them.  The host
  drafter below is simply not constructed in that mode.

Both drafters feed the same verify/accept machinery and both are
greedy-token-identical to vanilla decoding — the drafter only moves
WHICH positions get scored per forward, never what gets committed.

Determinism matters: the n-gram drafter is pure host state derived
from the committed token stream, so a slot proposes the same drafts
whether it shares the batch with 0 or num_slots-1 neighbours — a
prerequisite for the engine's greedy spec/vanilla token-identity
invariant.  (The heads drafter gets the same property for free: its
drafts are a pure function of device state that the identity invariant
already pins.)
"""
from __future__ import annotations

from typing import List, Sequence


class NGramDrafter:
    """Prompt-lookup drafter over one slot's committed token history.

    ``propose(k)`` scans for the most recent earlier occurrence of the
    longest history suffix (n-gram sizes ``max_n`` down to ``min_n``) and
    proposes the k tokens that followed it; when no n-gram matches it
    falls back to repeating the last committed token (free to verify,
    and correct surprisingly often on degenerate/looping streams).
    """

    def __init__(self, prompt: Sequence[int], max_n: int = 3, min_n: int = 1):
        if max_n < min_n or min_n < 1:
            raise ValueError(f"bad n-gram range [{min_n}, {max_n}]")
        self.history: List[int] = [int(t) for t in prompt]
        self.max_n = max_n
        self.min_n = min_n

    def extend(self, tokens: Sequence[int]):
        """Append newly committed tokens to the lookup history."""
        self.history.extend(int(t) for t in tokens)

    def propose(self, k: int) -> List[int]:
        """k draft tokens continuing the current history (deterministic)."""
        h = self.history
        if not h:
            return [0] * k
        for n in range(min(self.max_n, len(h) - 1), self.min_n - 1, -1):
            suffix = h[-n:]
            # most recent earlier occurrence of the suffix
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == suffix:
                    cont = h[i + n:i + n + k]
                    if cont:
                        return cont + [h[-1]] * (k - len(cont))
        return [h[-1]] * k
