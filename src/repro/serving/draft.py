"""Deterministic self-drafting proposers for speculative decoding.

The engine's verify step makes k extra decode-boundary crossings cheap
(the spike/int8 wire carries them as coded counts), so even a trivial
host-side drafter buys real speedup whenever its guesses land.  The
default here is prompt-lookup / n-gram drafting (no draft model, no
extra device work): match the longest recent suffix of the slot's token
history against earlier occurrences and propose the continuation that
followed last time.  On repetitive workloads (code, structured text,
copy-heavy prompts) acceptance is high; on incompressible streams it
degrades gracefully to vanilla decoding (the verify step still commits
one token per step, exactly like spec_k=0).

Determinism matters: the drafter is pure host state derived from the
committed token stream, so a slot proposes the same drafts whether it
shares the batch with 0 or num_slots-1 neighbours — a prerequisite for
the engine's greedy spec/vanilla token-identity invariant.
"""
from __future__ import annotations

from typing import List, Sequence


class NGramDrafter:
    """Prompt-lookup drafter over one slot's committed token history.

    ``propose(k)`` scans for the most recent earlier occurrence of the
    longest history suffix (n-gram sizes ``max_n`` down to ``min_n``) and
    proposes the k tokens that followed it; when no n-gram matches it
    falls back to repeating the last committed token (free to verify,
    and correct surprisingly often on degenerate/looping streams).
    """

    def __init__(self, prompt: Sequence[int], max_n: int = 3, min_n: int = 1):
        if max_n < min_n or min_n < 1:
            raise ValueError(f"bad n-gram range [{min_n}, {max_n}]")
        self.history: List[int] = [int(t) for t in prompt]
        self.max_n = max_n
        self.min_n = min_n

    def extend(self, tokens: Sequence[int]):
        """Append newly committed tokens to the lookup history."""
        self.history.extend(int(t) for t in tokens)

    def propose(self, k: int) -> List[int]:
        """k draft tokens continuing the current history (deterministic)."""
        h = self.history
        if not h:
            return [0] * k
        for n in range(min(self.max_n, len(h) - 1), self.min_n - 1, -1):
            suffix = h[-n:]
            # most recent earlier occurrence of the suffix
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == suffix:
                    cont = h[i + n:i + n + k]
                    if cont:
                        return cont + [h[-1]] * (k - len(cont))
        return [h[-1]] * k
