"""int8 gradient compression with error feedback (pod-boundary DP trick).

For cross-pod data-parallel gradient reduction the wire cost is
(pod-1)/pod x grad bytes; int8 quantization with an error-feedback
accumulator (1-bit-Adam / EF-SGD family) cuts it 4x vs f32 / 2x vs bf16
with no asymptotic convergence penalty.  This composes with the spike
codec: the paper's technique handles *activations*, this handles
*gradients* — together they cover both directions of pod-boundary
traffic (EXPERIMENTS.md §Perf, beyond-paper iteration).

Used by examples with replicated-param DP, and by the hillclimbed train
step for the explicit grad psums of replicated params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_i8(x, axis=-1):
    """Per-slice absmax int8 quantization -> (wire, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    s = jnp.maximum(amax, 1e-12) / 127.0
    return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s


def dequantize_i8(wire, s):
    return wire.astype(s.dtype) * s


def psum_compressed(g, axis_name, err=None):
    """psum(g) over ``axis_name`` with an int8 wire + error feedback.

    Implemented as all_to_all(int8) + local f32 accumulate + all_gather
    (same wire bytes as a ring all-reduce at int8, no overflow).  Returns
    (g_reduced, new_err).  ``err`` is the residual carried across steps.
    """
    n = lax.axis_size(axis_name)
    orig_shape = g.shape
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    flat = gf.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    wire, s = quantize_i8(flat.reshape(n, -1), axis=-1)
    new_err = (flat - dequantize_i8(wire, s).reshape(-1)).reshape(-1)
    new_err = new_err[:gf.size].reshape(orig_shape) if pad else \
        new_err.reshape(orig_shape)
    # reduce-scatter at int8: exchange shards, accumulate decoded f32
    shards = lax.all_to_all(wire, axis_name, split_axis=0, concat_axis=0,
                            tiled=False)                       # [n, chunk]
    s_all = lax.all_gather(s, axis_name, axis=0, tiled=False)  # [n, n, 1]
    own = lax.axis_index(axis_name)
    dec = shards.astype(jnp.float32) * s_all[:, own]
    acc = jnp.sum(dec, axis=0)                                 # [chunk]
    # all-gather the reduced shards back (int8 again for the wire)
    w2, s2 = quantize_i8(acc[None, :], axis=-1)
    w2g = lax.all_gather(w2[0], axis_name, axis=0, tiled=False)
    s2g = lax.all_gather(s2, axis_name, axis=0, tiled=False)
    full = (w2g.astype(jnp.float32) * s2g[:, 0]).reshape(-1)
    out = full[:gf.size].reshape(orig_shape)
    return out.astype(g.dtype), new_err.astype(jnp.float32)


def tree_psum_compressed(grads, axis_name, err_tree=None):
    """Apply psum_compressed over a pytree; threads error-feedback state."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = (jax.tree.leaves(err_tree) if err_tree is not None
            else [None] * len(leaves))
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        o, ne = psum_compressed(g, axis_name, e)
        outs.append(o)
        new_errs.append(ne)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_errs))
