"""AdamW with warmup-cosine schedule and global-norm clipping.

States (m, v, count) are sharded exactly like the params (ZeRO-1): the
update is purely elementwise, so no optimizer collectives are needed.
fp32 moments over bf16 params (mixed-precision production standard).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "count": jnp.zeros((), jnp.int32)}


def abstract_opt_state(params):
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, F32), params)
    return {"m": z, "v": z, "count": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_specs(pspecs):
    from jax.sharding import PartitionSpec as P
    return {"m": pspecs, "v": pspecs, "count": P()}


def schedule(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def apply_updates(params, grads, opt_state, *, gnorm=None,
                  cfg: AdamWConfig = AdamWConfig()):
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    if gnorm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        scale = 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(F32)
    bc2 = 1 - b2 ** count.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(F32)
        p2 = p.astype(F32) - lr * step
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}
