"""Backfill newer jax APIs on older installs (no new dependencies).

The codebase targets the current jax API surface (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``, ``lax.axis_size``, ``jax.make_mesh``
with ``axis_types``).  Some execution environments pin an older jax (e.g.
0.4.x) where those names live elsewhere or don't exist; importing
``repro`` installs small forwarding shims so the same code runs on both.
Each shim is a no-op when the real API is present.
"""
from __future__ import annotations

import functools
import inspect

import jax


def _install_shard_map():
    if hasattr(jax, "shard_map"):
        try:
            if "check_vma" in inspect.signature(jax.shard_map).parameters:
                return
        except (TypeError, ValueError):
            return
    from jax.experimental.shard_map import shard_map as _sm

    @functools.wraps(_sm)
    def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kw):
        if check_rep is None:
            check_rep = bool(check_vma) if check_vma is not None else True
        return _sm(f, mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep, **kw)

    jax.shard_map = shard_map


def _install_axis_type():
    if not hasattr(jax.sharding, "AxisType"):
        import enum

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType


def _install_make_mesh():
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return
    if "axis_types" in params:
        return
    _mm = jax.make_mesh

    @functools.wraps(_mm)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        return _mm(axis_shapes, axis_names, **kw)

    jax.make_mesh = make_mesh


def _install_axis_size():
    from jax import lax
    if hasattr(lax, "axis_size"):
        return
    from jax._src.core import axis_frame

    def axis_size(axis_name):
        if isinstance(axis_name, (tuple, list)):
            n = 1
            for a in axis_name:
                n *= axis_frame(a)
            return n
        return axis_frame(axis_name)   # static int inside shard_map

    lax.axis_size = axis_size


def install():
    _install_shard_map()
    _install_axis_type()
    _install_make_mesh()
    _install_axis_size()


install()
