"""Mixture-of-Experts FFN with expert parallelism over the tp axis.

Tokens are routed locally (seq-parallel domain — MoE is token-wise, so no
seq gather is needed), dispatched to their experts with a capacity-bound
all_to_all, computed, and combined with a second all_to_all.  Both
all_to_alls carry the spike wire — the paper's technique applied to the
MoE boundary (its dispatch tensors are exactly "activations crossing
chips").

Experts that don't divide tp are padded with dummy experts whose router
logits are masked to -inf (qwen2-moe: 60 -> 64).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..core import boundary
from . import common
from .context import Context, fsdp_gather
from .params import pdef, spike_pdefs


def moe_dims(cfg, tp):
    E = cfg.padded(cfg.n_experts, tp)
    return dict(E=E, E_loc=E // tp, Fe=cfg.d_ff_expert,
                n_real=cfg.n_experts,
                Fs=cfg.n_shared_experts * cfg.d_ff_expert)


def moe_defs(cfg, tp):
    d = moe_dims(cfg, tp)
    D = cfg.d_model
    defs = {
        "ln2": pdef(D, init="zeros"),
        "wr": pdef(D, d["E"], init="normal", scale=0.02,
                   dtype=jnp.float32),
        "we1": pdef(d["E"], D, d["Fe"], tp=0, fsdp=1),
        "we3": pdef(d["E"], D, d["Fe"], tp=0, fsdp=1),
        "we2": pdef(d["E"], d["Fe"], D, tp=0, fsdp=1),
        "sp_disp": spike_pdefs(D),
        "sp_comb": spike_pdefs(D),
    }
    if d["Fs"]:
        defs["ws1"] = pdef(D, d["Fs"], fsdp=0)
        defs["ws3"] = pdef(D, d["Fs"], fsdp=0)
        defs["ws2"] = pdef(d["Fs"], D, fsdp=1)
    if cfg.hnn_mode == "snn":
        defs["sp_snn2"] = spike_pdefs(D)
    return defs


def _route(cfg, d, h2, wr):
    """h2 [T, D] -> (gates [T,k], idx [T,k], aux_loss)."""
    T = h2.shape[0]
    k = cfg.top_k
    logits = (h2.astype(jnp.float32) @ wr.astype(jnp.float32))
    emask = jnp.arange(d["E"]) < d["n_real"]
    logits = jnp.where(emask[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], d["E"]), axis=0)
    aux = d["n_real"] * jnp.sum(me * ce)
    return gates, idx, aux


def moe_fwd(p, x, ctx: Context, aux_in):
    """x [B_loc, S_loc, D] (or [B,1,D] decode) -> (x', penalty, occ)."""
    cfg = ctx.cfg
    d = moe_dims(cfg, ctx.tp_size)
    B, S_loc, D = x.shape
    T = B * S_loc
    k = cfg.top_k

    h = common.norm(x, p["ln2"], cfg.norm)
    h2 = h.reshape(T, D)
    pen, occ = _stats(h2, p["sp_disp"], ctx)

    gates, idx, auxl = _route(cfg, d, h2, p["wr"])

    # capacity (tokens per expert per device); decode batches are tiny so
    # use a generous factor to avoid drops
    cf = cfg.capacity_factor if ctx.mode == "train" else 4.0
    C = max(1, math.ceil(T * k / d["E"] * cf))
    # rank of each assignment within its expert
    onehot = jax.nn.one_hot(idx, d["E"], dtype=jnp.int32)   # [T,k,E]
    flat = onehot.reshape(T * k, d["E"])
    ranks = jnp.cumsum(flat, axis=0) - flat
    rank = jnp.sum(ranks * flat, axis=-1)                    # [T*k]
    e_fl = idx.reshape(-1)
    keep = (rank < C)
    r_fl = jnp.clip(rank, 0, C - 1)
    tok_fl = jnp.repeat(jnp.arange(T), k)

    # dispatch buffer [E, C, D]
    buf = jnp.zeros((d["E"], C, D), h2.dtype)
    contrib = h2[tok_fl] * keep[:, None].astype(h2.dtype)
    buf = buf.at[e_fl, r_fl].add(contrib)

    # ---- boundary: EP all_to_all (spike wire) -> [E_loc, tp*C, D]
    if ctx.tp_size > 1:
        xb = boundary.coded_all_to_all(buf, p["sp_disp"], ctx.codec, ctx.tp,
                                       split_axis=0, concat_axis=1)
    else:
        xb = buf

    we1 = fsdp_gather(p["we1"], ctx, 1)
    we3 = fsdp_gather(p["we3"], ctx, 1)
    we2 = fsdp_gather(p["we2"], ctx, 1)
    hh = common.act_fn(jnp.einsum("ecd,edf->ecf", xb, we1), cfg.act) \
        * jnp.einsum("ecd,edf->ecf", xb, we3)
    yb = jnp.einsum("ecf,efd->ecd", hh, we2)

    # ---- boundary: combine all_to_all (spike wire) -> [E, C, D]
    if ctx.tp_size > 1:
        yb = boundary.coded_all_to_all(yb, p["sp_comb"], ctx.codec, ctx.tp,
                                       split_axis=1, concat_axis=0)

    # combine back to tokens
    y_fl = yb.reshape(d["E"] * C, D)[e_fl * C + r_fl]
    y_fl = y_fl * (gates.reshape(-1, 1) * keep[:, None]).astype(y_fl.dtype)
    y = jnp.zeros((T, D), y_fl.dtype).at[tok_fl].add(y_fl)

    # shared experts: fully-local dense gated MLP (no collective)
    if d["Fs"]:
        ws1 = fsdp_gather(p["ws1"], ctx, 0)
        ws3 = fsdp_gather(p["ws3"], ctx, 0)
        ws2 = fsdp_gather(p["ws2"], ctx, 1)
        y = y + (common.act_fn(h2 @ ws1, cfg.act) * (h2 @ ws3)) @ ws2

    y = y.reshape(B, S_loc, D)
    if cfg.hnn_mode == "snn":
        from .blocks_attn import _maybe_snn
        y = _maybe_snn(y, p.get("sp_snn2"), ctx)
    pen = pen + 0.01 * auxl.astype(jnp.float32)
    return x + y, pen, occ


def _stats(h, p, ctx):
    if ctx.mode == "train" and ctx.collect_stats:
        pen, occ = boundary.boundary_penalty(h, p, ctx.codec)
        return pen.astype(jnp.float32), occ.astype(jnp.float32)
    z = jnp.zeros((), jnp.float32)
    return z, z
