"""Learned draft heads (Medusa/EAGLE-style) for device-side drafting.

H small residual-MLP heads read the trunk's final D-space hidden state —
the tensor the decode/verify step already computes AND already moves
through the ``sp_head`` wire roundtrip when tp > 1.  Post-roundtrip that
hidden is bit-identical on every tp rank, and the head parameters are
replicated (no tp/fsdp dims), so drafting adds ~zero trunk FLOPs and
ZERO new collectives: head j's hidden is computed redundantly per rank,
its local-vocab logits reuse the tp-sharded LM head, and the engine
turns them into draft tokens with the same distributed argmax the
sampler uses.  Only accepted tokens ever cross the die boundary.

Head j predicts the token at offset j+1 past the next token (the trunk's
own argmax is offset 0): a residual MLP ``z_j = h + W2_j silu(W1_j h +
b1_j)`` with ``W2 = 0`` at init, so an untrained head is exactly the
identity — its argmax repeats the trunk's next-token argmax, which is a
safe (garbage-tolerant) draft under longest-prefix acceptance.

Training is frozen-trunk (``launch.train.make_draft_head_train_step``):
the trunk forward runs under ``stop_gradient``, a next-k-token
distributed-XE objective trains only the ``"draft_heads"`` subtree, and
the heads checkpoint alongside the trunk as one params tree (the
checkpoint manager is path-keyed, so trunk-only checkpoints coexist).

This module is layered below ``repro.serving`` and must not import it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core import boundary
from . import common
from . import model as M
from .context import Context
from .params import pdef

F32 = jnp.float32


def draft_head_defs(cfg, num_heads: int, d_hidden: int = 0):
    """ParamDefs for H stacked residual-MLP draft heads.

    No tp/fsdp dims: the heads replicate on every rank (their input is
    the post-roundtrip replicated hidden), so grads psum over all mesh
    axes and serving needs no new weight collectives.  ``w2`` starts at
    zero: identity heads, safe drafts from step one.
    """
    D = cfg.d_model
    Dh = int(d_hidden) if d_hidden else max(D // 2, 8)
    return {"w1": pdef(num_heads, D, Dh),
            "b1": pdef(num_heads, Dh, init="zeros"),
            "w2": pdef(num_heads, Dh, D, init="zeros")}


def num_draft_heads(params) -> int:
    return int(params["draft_heads"]["w1"].shape[0])


def head_hiddens(hp, h):
    """All heads at once: h [..., D] -> drafted hiddens [..., H, D]."""
    dt = h.dtype
    u = jnp.einsum("...d,hdk->...hk", h, hp["w1"].astype(dt))
    u = jax.nn.silu(u + hp["b1"].astype(dt))
    return h[..., None, :] + jnp.einsum("...hk,hkd->...hd", u,
                                        hp["w2"].astype(dt))


def head_hidden_one(hp, j: int, h):
    """Single head j: h [..., D] -> z_j [..., D] (loss-loop friendly)."""
    dt = h.dtype
    u = jax.nn.silu(h @ hp["w1"][j].astype(dt) + hp["b1"][j].astype(dt))
    return h + u @ hp["w2"][j].astype(dt)


def _dist_nll(logits_loc, labels_g, ctx: Context):
    """Distributed XE over the tp-sharded vocab with ALREADY-GATHERED
    labels [B, S] (the next-k objective shifts labels by j+1 AFTER the
    seq gather — shifting per-shard would be wrong at shard seams, so
    ``model.xent_loss`` cannot be reused here).  Returns (nll [B, S],
    hit [B, S]) where hit flags gold == the global argmax logit.
    """
    cfg = ctx.cfg
    if cfg.final_softcap:
        logits_loc = common.softcap(logits_loc, cfg.final_softcap)
    if ctx.tp_size == 1:
        lse = jax.nn.logsumexp(logits_loc, axis=-1)
        gold = jnp.take_along_axis(
            logits_loc, labels_g[..., None], axis=-1)[..., 0]
        gmax = jnp.max(logits_loc, axis=-1)
        return lse - gold, (gold >= gmax).astype(F32)
    V_loc = logits_loc.shape[-1]
    r = lax.axis_index(ctx.tp)
    off = r * V_loc
    m_loc = jnp.max(logits_loc, axis=-1)
    m = lax.stop_gradient(lax.pmax(lax.stop_gradient(m_loc), ctx.tp))
    se = lax.psum(jnp.sum(jnp.exp(logits_loc - m[..., None]), -1), ctx.tp)
    lse = m + jnp.log(se)
    loc = jnp.clip(labels_g - off, 0, V_loc - 1)
    gold_p = jnp.take_along_axis(logits_loc, loc[..., None], -1)[..., 0]
    valid = (labels_g >= off) & (labels_g < off + V_loc)
    gold = lax.psum(jnp.where(valid, gold_p, 0.0), ctx.tp)
    return lse - gold, (gold >= m).astype(F32)


def draft_head_loss(params, batch, ctx: Context):
    """Frozen-trunk next-k-token objective.

    batch: tokens/labels [B_loc, S_loc] (labels[t] = token t+1, the
    standard LM shift).  Head j at position t predicts labels[t + j + 1];
    the tail j+1 positions of each row are masked.  The trunk forward
    (embed -> stack -> final norm -> seq gather) runs under
    ``stop_gradient`` so the backward touches only the heads.

    Returns (loss / dp_size, metrics) — same normalization contract as
    ``model.forward_loss`` (grads are psum'd over dp for replicated
    leaves, so each dp rank contributes mean-loss / dp_size).
    """
    cfg = ctx.cfg
    aux = M._make_aux(batch, ctx)
    x = M.embed_tokens(params, batch["tokens"], ctx)
    x, _, _, _ = M._run_stack(params, x, ctx, aux)
    h = common.norm(x, params["final_ln"], cfg.norm)
    if ctx.tp_size > 1:
        xg = boundary.coded_all_gather(h, params["sp_head"], ctx.codec,
                                       ctx.tp, axis=1)
        labels = lax.all_gather(batch["labels"], ctx.tp, axis=1, tiled=True)
    else:
        xg, labels = h, batch["labels"]
    xg = lax.stop_gradient(xg)
    head = lax.stop_gradient(M._head_w(params, ctx))          # [D, V_loc]

    hp = params["draft_heads"]
    H = hp["w1"].shape[0]
    B, S, _ = xg.shape
    pos = jnp.arange(S)[None, :]
    loss = jnp.zeros((), F32)
    acc = jnp.zeros((), F32)
    for j in range(H):
        z = head_hidden_one(hp, j, xg)
        logits = (z @ head).astype(F32)                       # [B,S,V_loc]
        lab_j = jnp.roll(labels, -(j + 1), axis=1)
        mask = (pos < S - (j + 1)).astype(F32) * jnp.ones((B, 1), F32)
        nll, hit = _dist_nll(logits, lab_j, ctx)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = loss + jnp.sum(nll * mask) / denom
        acc = acc + jnp.sum(hit * mask) / denom
    loss = loss / H
    metrics = {"loss": loss, "draft_acc": acc / H}
    return loss / ctx.dp_size, metrics
