"""Parameter definitions: global shapes + sharding specs built together.

Every parameter is described once by a ``ParamDef`` (global shape, which
dim is tensor-parallel, which dim is FSDP-sharded, initializer).  From the
defs we derive: init (sharded via jit out_shardings), the shard_map
in_specs tree, and the set of mesh axes each gradient must be psum'd over
(axes absent from the spec).

Conventions:
  * tp_dim: sharded over the "model" axis.
  * fsdp_dim: sharded over the data axes ("pod","data") — ZeRO-3 style;
    gathered per-layer inside the scan body.
  * 1-D / small params (norm scales, spike thresholds, biases) replicate.
  * unit-stacked params get a leading U dim (never sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    tp_dim: Optional[int] = None
    fsdp_dim: Optional[int] = None
    init: str = "normal"      # normal|zeros|ones|alog|theta|logscale|embed
    scale: float = 0.02
    dtype: Any = None         # None -> cfg dtype


def pdef(*shape, tp=None, fsdp=None, init="normal", scale=0.02, dtype=None):
    return ParamDef(tuple(shape), tp, fsdp, init, scale, dtype)


def stack_defs(defs, U: int):
    """Prepend the unit dim to every def in a pytree of ParamDefs."""
    def f(d: ParamDef) -> ParamDef:
        tp = None if d.tp_dim is None else d.tp_dim + 1
        fs = None if d.fsdp_dim is None else d.fsdp_dim + 1
        return ParamDef((U,) + d.shape, tp, fs, d.init, d.scale, d.dtype)
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def spec_of(d: ParamDef, dp_axes, tp_axis) -> P:
    entries = [None] * len(d.shape)
    if d.tp_dim is not None:
        entries[d.tp_dim] = tp_axis
    if d.fsdp_dim is not None:
        entries[d.fsdp_dim] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*entries)


def specs_tree(defs, dp_axes, tp_axis):
    return jax.tree.map(lambda d: spec_of(d, dp_axes, tp_axis), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def grad_psum_axes(defs, dp_axes, tp_axis):
    """Mesh axes each grad must be psum'd over = axes not in the spec."""
    def f(d: ParamDef):
        axes = []
        if d.tp_dim is None:
            axes.append(tp_axis)
        if d.fsdp_dim is None:
            axes.extend(dp_axes)
        return tuple(axes)
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _init_leaf(d: ParamDef, key, dtype):
    dt = d.dtype or dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "normal" or d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32)
                * d.scale).astype(dt)
    if d.init == "alog":   # mamba A_log: log(1..N) per state
        n = d.shape[-1]
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), d.shape[:-1] + (1,))
        return jnp.log(a).astype(dt)
    if d.init == "theta":  # spike firing gate
        return jnp.full(d.shape, 0.01, jnp.float32)
    if d.init == "logscale":
        return jnp.zeros(d.shape, jnp.float32)
    if d.init == "dtbias":  # mamba dt bias: softplus^-1 of ~0.01..0.1
        return jnp.full(d.shape, -4.6, jnp.float32)
    if d.init == "half":
        return jnp.full(d.shape, 0.5, jnp.float32)
    raise ValueError(d.init)


def init_params(defs, key, dtype=jnp.bfloat16):
    """Materialize a defs pytree into arrays (host-side, unsharded)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (for .lower() without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def spike_pdefs(dim: int):
    """Learnable boundary codec params for one boundary of width dim."""
    return {"theta": pdef(dim, init="theta", dtype=jnp.float32),
            "log_scale": pdef(dim, init="logscale", dtype=jnp.float32)}
