"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RWKV (the paper's LM).

xLSTM blocks are self-contained (d_ff = 0): the mixer includes its own
up/down projections.  Heads are TP-sharded; seq gather in / partial-sum
scatter out are the spike boundaries, as elsewhere.

Recurrences run as lax.scan over seq chunks with a jax.checkpoint'd chunk
body, so the backward pass stores only chunk-boundary states (the
standard linear-RNN memory trick) — important for the mLSTM matrix state
[B, H, dh, dh].

RWKV follows the paper's benchmark model (RWKV-4-style time-mix +
channel-mix with the numerically-stable wkv recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..core import boundary
from . import common
from .context import Context, fsdp_gather
from .params import pdef, spike_pdefs

F32 = jnp.float32


def _stats(h, p, ctx):
    if ctx.mode == "train" and ctx.collect_stats:
        pen, occ = boundary.boundary_penalty(h, p, ctx.codec)
        return pen.astype(jnp.float32), occ.astype(jnp.float32)
    z = jnp.zeros((), jnp.float32)
    return z, z


# ===========================================================================
# mLSTM (xLSTM matrix-memory block)
# ===========================================================================


def mlstm_dims(cfg, tp):
    H = cfg.padded(cfg.n_heads, tp)
    dh = cfg.d_model // cfg.n_heads
    return dict(H=H, H_loc=H // tp, dh=dh)


def mlstm_defs(cfg, tp):
    d = mlstm_dims(cfg, tp)
    D, dh = cfg.d_model, d["dh"]
    return {
        "ln": pdef(D, init="zeros"),
        "wq": pdef(D, d["H"] * dh, tp=1, fsdp=0),
        "wk": pdef(D, d["H"] * dh, tp=1, fsdp=0),
        "wv": pdef(D, d["H"] * dh, tp=1, fsdp=0),
        # [D, 2, H] with tp on the head dim so each rank owns (i,f) for
        # its heads (sharding a concatenated 2H dim would interleave gates)
        "wif": pdef(D, 2, d["H"], tp=2, scale=0.05),    # i,f gate logits
        "wg": pdef(D, d["H"] * dh, tp=1, fsdp=0),       # output gate
        "wo": pdef(d["H"] * dh, D, tp=0, fsdp=1),
        "sp_in": spike_pdefs(D),
        "sp_out": spike_pdefs(D),
    }


def mlstm_cache_defs(cfg, tp, B_loc, dtype):
    d = mlstm_dims(cfg, tp)
    return {
        "C": jax.ShapeDtypeStruct((B_loc, d["H_loc"], d["dh"], d["dh"]), F32),
        "n": jax.ShapeDtypeStruct((B_loc, d["H_loc"], d["dh"]), F32),
        "m": jax.ShapeDtypeStruct((B_loc, d["H_loc"]), F32),
    }


def _mlstm_cell(state, qkvif):
    """One stabilized mLSTM step (xLSTM paper eqs 19-27)."""
    C, n, m = state
    q, k, v, ig, fg = qkvif                          # [B,H,dh]x3, [B,H]x2
    m_new = jnp.maximum(fg + m, ig)
    f_eff = jnp.exp(fg + m - m_new)
    i_eff = jnp.exp(ig - m_new)
    C_new = f_eff[..., None, None] * C + \
        i_eff[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhij,bhi->bhj", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_scan(q, k, v, ig, fg, state, chunk=64):
    """q,k,v [B,S,H,dh]; ig,fg [B,S,H].  Returns (h [B,S,H,dh], state)."""
    B, S, H, dh = q.shape
    ch = min(chunk, S)
    nc = S // ch

    def chunk_body(state, blk):
        qs, ks, vs, igs, fgs = blk                   # [ch, B, H, ...]

        def step(st, t):
            return _mlstm_cell(st, (qs[t], ks[t], vs[t], igs[t], fgs[t]))

        st, hs = lax.scan(step, state, jnp.arange(ch))
        return st, hs

    blks = (q.transpose(1, 0, 2, 3).reshape(nc, ch, B, H, dh),
            k.transpose(1, 0, 2, 3).reshape(nc, ch, B, H, dh),
            v.transpose(1, 0, 2, 3).reshape(nc, ch, B, H, dh),
            ig.transpose(1, 0, 2).reshape(nc, ch, B, H),
            fg.transpose(1, 0, 2).reshape(nc, ch, B, H))
    state, hs = lax.scan(jax.checkpoint(chunk_body), state, blks)
    h = hs.reshape(S, B, H, dh).transpose(1, 0, 2, 3)
    return h, state


def mlstm_fwd(p, x, ctx: Context, aux):
    cfg = ctx.cfg
    d = mlstm_dims(cfg, ctx.tp_size)
    h_in = common.norm(x, p["ln"], cfg.norm)
    pen, occ = _stats(h_in, p["sp_in"], ctx)
    xg = boundary.coded_all_gather(h_in, p["sp_in"], ctx.codec, ctx.tp,
                                   axis=1)
    B, S, D = xg.shape
    dh = d["dh"]

    wq = fsdp_gather(p["wq"], ctx, 0)
    wk = fsdp_gather(p["wk"], ctx, 0)
    wv = fsdp_gather(p["wv"], ctx, 0)
    wg = fsdp_gather(p["wg"], ctx, 0)
    q = (xg @ wq).reshape(B, S, d["H_loc"], dh).astype(F32)
    k = (xg @ wk).reshape(B, S, d["H_loc"], dh).astype(F32) / (dh ** 0.5)
    v = (xg @ wv).reshape(B, S, d["H_loc"], dh).astype(F32)
    gif = jnp.einsum("bsd,dgh->bsgh", xg.astype(F32),
                     p["wif"].astype(F32))            # [B,S,2,H_loc]
    ig = gif[:, :, 0]
    fg = jax.nn.log_sigmoid(gif[:, :, 1])

    state = (jnp.zeros((B, d["H_loc"], dh, dh), F32),
             jnp.zeros((B, d["H_loc"], dh), F32),
             jnp.zeros((B, d["H_loc"]), F32))
    hseq, state = _mlstm_scan(q, k, v, ig, fg, state)
    og = jax.nn.sigmoid((xg @ wg).astype(F32)).reshape(B, S, d["H_loc"], dh)
    y = (hseq * og).reshape(B, S, d["H_loc"] * dh).astype(x.dtype)

    wo = fsdp_gather(p["wo"], ctx, 1)
    part = y @ wo
    out = boundary.coded_psum_scatter(part, p["sp_out"], ctx.codec, ctx.tp,
                                      axis=1)
    cache = None
    if ctx.mode == "prefill":
        cache = {"C": state[0], "n": state[1], "m": state[2]}
    return x + out, cache, pen, occ


def mlstm_decode_fwd(p, x, cache, pos, ctx: Context, aux):
    cfg = ctx.cfg
    d = mlstm_dims(cfg, ctx.tp_size)
    B = x.shape[0]
    dh = d["dh"]
    h_in = common.norm(x, p["ln"], cfg.norm)[:, 0]
    h_in = boundary.wire_roundtrip(h_in, p["sp_in"], ctx.codec)

    wq = fsdp_gather(p["wq"], ctx, 0)
    wk = fsdp_gather(p["wk"], ctx, 0)
    wv = fsdp_gather(p["wv"], ctx, 0)
    wg = fsdp_gather(p["wg"], ctx, 0)
    q = (h_in @ wq).reshape(B, d["H_loc"], dh).astype(F32)
    k = (h_in @ wk).reshape(B, d["H_loc"], dh).astype(F32) / (dh ** 0.5)
    v = (h_in @ wv).reshape(B, d["H_loc"], dh).astype(F32)
    gif = jnp.einsum("bd,dgh->bgh", h_in.astype(F32),
                     p["wif"].astype(F32))            # [B,2,H_loc]
    ig = gif[:, 0]
    fg = jax.nn.log_sigmoid(gif[:, 1])

    state = (cache["C"], cache["n"], cache["m"])
    state, h = _mlstm_cell(state, (q, k, v, ig, fg))
    og = jax.nn.sigmoid((h_in @ wg).astype(F32)).reshape(B, d["H_loc"], dh)
    y = (h * og).reshape(B, 1, d["H_loc"] * dh).astype(x.dtype)
    wo = fsdp_gather(p["wo"], ctx, 1)
    out = boundary.coded_psum(y @ wo, p["sp_out"], ctx.codec, ctx.tp)
    return x + out, {"C": state[0], "n": state[1], "m": state[2]}


# ===========================================================================
# sLSTM (xLSTM scalar-memory block, block-diagonal recurrence)
# ===========================================================================


def slstm_defs(cfg, tp):
    d = mlstm_dims(cfg, tp)
    D, dh = cfg.d_model, d["dh"]
    return {
        "ln": pdef(D, init="zeros"),
        "wz": pdef(D, d["H"] * dh, tp=1, fsdp=0),
        # [D, 3, H*dh] with tp on the last dim (see mlstm wif note)
        "wgates": pdef(D, 3, d["H"] * dh, tp=2, fsdp=0),    # i,f,o
        "r": pdef(d["H"], dh, 4 * dh, tp=0, scale=0.05),    # recurrent (z,i,f,o)
        "wo": pdef(d["H"] * dh, D, tp=0, fsdp=1),
        "sp_in": spike_pdefs(D),
        "sp_out": spike_pdefs(D),
    }


def slstm_cache_defs(cfg, tp, B_loc, dtype):
    d = mlstm_dims(cfg, tp)
    shape = (B_loc, d["H_loc"], d["dh"])
    return {k: jax.ShapeDtypeStruct(shape, F32) for k in ("c", "n", "h", "m")}


def _slstm_cell(state, zifo, r):
    """Stabilized sLSTM step; r [H, dh, 4dh] block-diag recurrence."""
    c, n, h, m = state                              # [B,H,dh]
    rec = jnp.einsum("bhi,hij->bhj", h, r)          # [B,H,4dh]
    dh = c.shape[-1]
    z_r, i_r, f_r, o_r = jnp.split(rec, 4, axis=-1)
    z_x, i_x, f_x, o_x = zifo
    z = jnp.tanh(z_x + z_r)
    i_t = i_x + i_r
    f_t = jax.nn.log_sigmoid(f_x + f_r)
    o = jax.nn.sigmoid(o_x + o_r)
    m_new = jnp.maximum(f_t + m, i_t)
    i_eff = jnp.exp(i_t - m_new)
    f_eff = jnp.exp(f_t + m - m_new)
    c_new = f_eff * c + i_eff * z
    n_new = f_eff * n + i_eff
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_scan(zx, ix, fx, ox, r, state, chunk=64):
    B, S, H, dh = zx.shape
    ch = min(chunk, S)
    nc = S // ch

    def chunk_body(state, blk):
        zs, is_, fs, os_ = blk

        def step(st, t):
            return _slstm_cell(st, (zs[t], is_[t], fs[t], os_[t]), r)

        return lax.scan(step, state, jnp.arange(ch))

    mk = lambda a: a.transpose(1, 0, 2, 3).reshape(nc, ch, B, H, dh)
    state, hs = lax.scan(jax.checkpoint(chunk_body), state,
                         (mk(zx), mk(ix), mk(fx), mk(ox)))
    return hs.reshape(S, B, H, dh).transpose(1, 0, 2, 3), state


def slstm_fwd(p, x, ctx: Context, aux):
    cfg = ctx.cfg
    d = mlstm_dims(cfg, ctx.tp_size)
    dh = d["dh"]
    h_in = common.norm(x, p["ln"], cfg.norm)
    pen, occ = _stats(h_in, p["sp_in"], ctx)
    xg = boundary.coded_all_gather(h_in, p["sp_in"], ctx.codec, ctx.tp,
                                   axis=1)
    B, S, D = xg.shape

    wz = fsdp_gather(p["wz"], ctx, 0)
    wg = fsdp_gather(p["wgates"], ctx, 0)
    zx = (xg @ wz).reshape(B, S, d["H_loc"], dh).astype(F32)
    gx = jnp.einsum("bsd,dgk->bsgk", xg.astype(F32), wg.astype(F32))
    gx = gx.reshape(B, S, 3, d["H_loc"], dh)
    ix, fx, ox = gx[:, :, 0], gx[:, :, 1], gx[:, :, 2]

    state = tuple(jnp.zeros((B, d["H_loc"], dh), F32) for _ in range(4))
    hseq, state = _slstm_scan(zx, ix, fx, ox, p["r"].astype(F32), state)
    y = hseq.reshape(B, S, d["H_loc"] * dh).astype(x.dtype)

    wo = fsdp_gather(p["wo"], ctx, 1)
    part = y @ wo
    out = boundary.coded_psum_scatter(part, p["sp_out"], ctx.codec, ctx.tp,
                                      axis=1)
    cache = None
    if ctx.mode == "prefill":
        cache = dict(zip(("c", "n", "h", "m"), state))
    return x + out, cache, pen, occ


def slstm_decode_fwd(p, x, cache, pos, ctx: Context, aux):
    cfg = ctx.cfg
    d = mlstm_dims(cfg, ctx.tp_size)
    dh = d["dh"]
    B = x.shape[0]
    h_in = common.norm(x, p["ln"], cfg.norm)[:, 0]
    h_in = boundary.wire_roundtrip(h_in, p["sp_in"], ctx.codec)
    wz = fsdp_gather(p["wz"], ctx, 0)
    wg = fsdp_gather(p["wgates"], ctx, 0)
    zx = (h_in @ wz).reshape(B, d["H_loc"], dh).astype(F32)
    gx = jnp.einsum("bd,dgk->bgk", h_in.astype(F32), wg.astype(F32))
    gx = gx.reshape(B, 3, d["H_loc"], dh)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state, h = _slstm_cell(state, (zx, gx[:, 0], gx[:, 1], gx[:, 2]),
                           p["r"].astype(F32))
    y = h.reshape(B, 1, d["H_loc"] * dh).astype(x.dtype)
    wo = fsdp_gather(p["wo"], ctx, 1)
    out = boundary.coded_psum(y @ wo, p["sp_out"], ctx.codec, ctx.tp)
    return x + out, dict(zip(("c", "n", "h", "m"), state))


# ===========================================================================
# RWKV (paper's language model; RWKV-4-style)
# ===========================================================================


def rwkv_dims(cfg, tp):
    C = cfg.padded(cfg.d_model, tp)
    return dict(C=C, C_loc=C // tp)


def rwkv_defs(cfg, tp):
    d = rwkv_dims(cfg, tp)
    D = cfg.d_model
    F = cfg.ff_padded(tp) or 4 * D
    return {
        "ln1": pdef(D, init="zeros"),
        "ln2": pdef(D, init="zeros"),
        "mix_kvr": pdef(3, D, init="half", dtype=jnp.float32),
        "mix_cm": pdef(2, D, init="half", dtype=jnp.float32),
        "time_decay": pdef(d["C"], tp=0, init="zeros", dtype=jnp.float32),
        "time_first": pdef(d["C"], tp=0, init="zeros", dtype=jnp.float32),
        "wk_tm": pdef(D, d["C"], tp=1, fsdp=0),
        "wv_tm": pdef(D, d["C"], tp=1, fsdp=0),
        "wr_tm": pdef(D, d["C"], tp=1, fsdp=0),
        "wo_tm": pdef(d["C"], D, tp=0, fsdp=1),
        "wk_cm": pdef(D, F, tp=1, fsdp=0),
        "wr_cm": pdef(D, D, fsdp=0),
        "wv_cm": pdef(F, D, tp=0, fsdp=1),
        "sp_in": spike_pdefs(D),
        "sp_out": spike_pdefs(D),
        "sp_in2": spike_pdefs(D),
        "sp_out2": spike_pdefs(D),
    }


def rwkv_cache_defs(cfg, tp, B_loc, dtype):
    d = rwkv_dims(cfg, tp)
    D = cfg.d_model
    return {
        "x_tm": jax.ShapeDtypeStruct((B_loc, D), dtype),
        "x_cm": jax.ShapeDtypeStruct((B_loc, D), dtype),
        "aa": jax.ShapeDtypeStruct((B_loc, d["C_loc"]), F32),
        "bb": jax.ShapeDtypeStruct((B_loc, d["C_loc"]), F32),
        "pp": jax.ShapeDtypeStruct((B_loc, d["C_loc"]), F32),
    }


def _wkv_step(state, kvu):
    """Numerically-stable RWKV wkv recurrence (one step)."""
    aa, bb, pp = state
    kt, vt, w, u = kvu
    ww = u + kt
    q = jnp.maximum(pp, ww)
    e1 = jnp.exp(pp - q)
    e2 = jnp.exp(ww - q)
    out = (e1 * aa + e2 * vt) / jnp.maximum(e1 * bb + e2, 1e-30)
    ww2 = pp + w
    q2 = jnp.maximum(ww2, kt)
    e1b = jnp.exp(ww2 - q2)
    e2b = jnp.exp(kt - q2)
    return (e1b * aa + e2b * vt, e1b * bb + e2b, q2), out


def _wkv_scan(k, v, w, u, state, chunk=64):
    """k,v [B,S,C]; w,u [C]."""
    B, S, C = k.shape
    ch = min(chunk, S)
    nc = S // ch

    def chunk_body(state, blk):
        ks, vs = blk

        def step(st, t):
            return _wkv_step(st, (ks[t], vs[t], w, u))

        return lax.scan(step, state, jnp.arange(ch))

    mk = lambda a: a.transpose(1, 0, 2).reshape(nc, ch, B, C)
    state, outs = lax.scan(jax.checkpoint(chunk_body), state, (mk(k), mk(v)))
    return outs.reshape(S, B, C).transpose(1, 0, 2), state


def _token_shift(xg, x_prev):
    """x_{t-1} stream: shift right by one, x_prev fills position 0."""
    return jnp.concatenate([x_prev[:, None, :], xg[:, :-1, :]], axis=1)


def rwkv_fwd(p, x, ctx: Context, aux):
    cfg = ctx.cfg
    d = rwkv_dims(cfg, ctx.tp_size)
    B, S_loc, D = x.shape

    # ---- time-mix ----
    h = common.norm(x, p["ln1"], cfg.norm)
    pen, occ = _stats(h, p["sp_in"], ctx)
    xg = boundary.coded_all_gather(h, p["sp_in"], ctx.codec, ctx.tp, axis=1)
    B, S, D = xg.shape
    xp = _token_shift(xg, jnp.zeros((B, D), xg.dtype))
    mk, mv, mr = p["mix_kvr"][0], p["mix_kvr"][1], p["mix_kvr"][2]
    mix = lambda m: (xg.astype(F32) * m + xp.astype(F32) * (1 - m)).astype(xg.dtype)
    wk = fsdp_gather(p["wk_tm"], ctx, 0)
    wv = fsdp_gather(p["wv_tm"], ctx, 0)
    wr = fsdp_gather(p["wr_tm"], ctx, 0)
    kt = (mix(mk) @ wk).astype(F32)
    vt = (mix(mv) @ wv).astype(F32)
    rt = jax.nn.sigmoid((mix(mr) @ wr).astype(F32))
    w = -jnp.exp(p["time_decay"])
    u = p["time_first"]
    state = (jnp.zeros((B, d["C_loc"]), F32), jnp.zeros((B, d["C_loc"]), F32),
             jnp.full((B, d["C_loc"]), -1e30, F32))
    wkv, state = _wkv_scan(kt, vt, w, u, state)
    y = (rt * wkv).astype(x.dtype)
    wo = fsdp_gather(p["wo_tm"], ctx, 1)
    part = y @ wo
    out1 = boundary.coded_psum_scatter(part, p["sp_out"], ctx.codec, ctx.tp,
                                       axis=1)
    if cfg.hnn_mode == "snn" and ctx.codec.mode != "none":
        out1 = boundary._local_roundtrip(out1, p["sp_out"], ctx.codec)
    x = x + out1

    # ---- channel-mix ----
    h2 = common.norm(x, p["ln2"], cfg.norm)
    pen2, occ2 = _stats(h2, p["sp_in2"], ctx)
    xg2 = boundary.coded_all_gather(h2, p["sp_in2"], ctx.codec, ctx.tp,
                                    axis=1)
    xp2 = _token_shift(xg2, jnp.zeros((B, D), xg2.dtype))
    mk2, mr2 = p["mix_cm"][0], p["mix_cm"][1]
    mix2 = lambda m: (xg2.astype(F32) * m + xp2.astype(F32) * (1 - m)).astype(xg2.dtype)
    wk2 = fsdp_gather(p["wk_cm"], ctx, 0)
    wr2 = fsdp_gather(p["wr_cm"], ctx, 0)
    wv2 = fsdp_gather(p["wv_cm"], ctx, 1)
    kk = jnp.square(jax.nn.relu(mix2(mk2) @ wk2))
    rr = jax.nn.sigmoid((mix2(mr2) @ wr2).astype(F32)).astype(x.dtype)
    part2 = kk @ wv2
    out2 = boundary.coded_psum_scatter(part2, p["sp_out2"], ctx.codec,
                                       ctx.tp, axis=1)
    # apply receptance gate in the sharded domain
    rr_loc = _shard_slice_seq(rr, ctx, S_loc)
    if cfg.hnn_mode == "snn" and ctx.codec.mode != "none":
        out2 = boundary._local_roundtrip(out2, p["sp_out2"], ctx.codec)
    x = x + rr_loc * out2
    cache = None
    if ctx.mode == "prefill":
        cache = {"x_tm": xg[:, -1].astype(x.dtype),
                 "x_cm": xg2[:, -1].astype(x.dtype),
                 "aa": state[0], "bb": state[1], "pp": state[2]}
    return x, cache, pen + pen2, occ * 0.5 + occ2 * 0.5


def _shard_slice_seq(full, ctx, S_loc):
    r = lax.axis_index(ctx.tp)
    return lax.dynamic_slice_in_dim(full, r * S_loc, S_loc, axis=1)


def rwkv_decode_fwd(p, x, cache, pos, ctx: Context, aux):
    cfg = ctx.cfg
    d = rwkv_dims(cfg, ctx.tp_size)
    B = x.shape[0]

    h = common.norm(x, p["ln1"], cfg.norm)[:, 0]
    h = boundary.wire_roundtrip(h, p["sp_in"], ctx.codec)
    xp = cache["x_tm"].astype(F32)
    mk, mv, mr = p["mix_kvr"][0], p["mix_kvr"][1], p["mix_kvr"][2]
    mix = lambda m: (h.astype(F32) * m + xp * (1 - m)).astype(h.dtype)
    wk = fsdp_gather(p["wk_tm"], ctx, 0)
    wv = fsdp_gather(p["wv_tm"], ctx, 0)
    wr = fsdp_gather(p["wr_tm"], ctx, 0)
    kt = (mix(mk) @ wk).astype(F32)
    vt = (mix(mv) @ wv).astype(F32)
    rt = jax.nn.sigmoid((mix(mr) @ wr).astype(F32))
    w = -jnp.exp(p["time_decay"])
    u = p["time_first"]
    state = (cache["aa"], cache["bb"], cache["pp"])
    state, wkv = _wkv_step(state, (kt, vt, w, u))
    y = (rt * wkv)[:, None, :].astype(x.dtype)
    wo = fsdp_gather(p["wo_tm"], ctx, 1)
    x = x + boundary.coded_psum(y @ wo, p["sp_out"], ctx.codec, ctx.tp)

    h2 = common.norm(x, p["ln2"], cfg.norm)[:, 0]
    h2 = boundary.wire_roundtrip(h2, p["sp_in2"], ctx.codec)
    xp2 = cache["x_cm"].astype(F32)
    mk2, mr2 = p["mix_cm"][0], p["mix_cm"][1]
    mix2 = lambda m: (h2.astype(F32) * m + xp2 * (1 - m)).astype(h2.dtype)
    wk2 = fsdp_gather(p["wk_cm"], ctx, 0)
    wr2 = fsdp_gather(p["wr_cm"], ctx, 0)
    wv2 = fsdp_gather(p["wv_cm"], ctx, 1)
    kk = jnp.square(jax.nn.relu(mix2(mk2) @ wk2))
    rr = jax.nn.sigmoid((mix2(mr2) @ wr2).astype(F32)).astype(x.dtype)
    out2 = boundary.coded_psum((kk @ wv2)[:, None, :], p["sp_out2"],
                               ctx.codec, ctx.tp)
    x = x + rr[:, None, :] * out2
    new_cache = {"x_tm": h.astype(cache["x_tm"].dtype),
                 "x_cm": h2.astype(cache["x_cm"].dtype),
                 "aa": state[0], "bb": state[1], "pp": state[2]}
    return x, new_cache
