"""Full model: embedding -> scanned layer units -> distributed LM loss.

Everything in this module runs *inside* shard_map (per-shard views).
Layers are scanned over homogeneous repeating units (the ``pattern`` in
the config) with per-unit FSDP weight gathers, MaxText-style; the scan
body is rematerialized (jax.checkpoint) so 72-layer x 398B configs
lower with per-layer activation memory only.

Distributed pieces:
  embedding  : vocab over tp; coded psum_scatter to the seq-sharded domain
  LM head    : seq gather (spike boundary) -> local-vocab logits ->
               cross-vocab softmax XE via pmax/psum over tp
  decode     : KV seq-sharded over ctx.cp (context parallel)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..core import boundary
from . import blocks_attn, blocks_moe, blocks_rnn, blocks_ssm, common
from .context import Context, fsdp_gather
from .params import (abstract_params, init_params, pdef, spike_pdefs,
                     stack_defs)

F32 = jnp.float32

BLOCK_DEFS = {
    "attn": lambda cfg, tp: {**blocks_attn.attn_defs(cfg, tp),
                             **blocks_attn.mlp_defs(cfg, tp)},
    "global": lambda cfg, tp: {**blocks_attn.attn_defs(cfg, tp),
                               **blocks_attn.mlp_defs(cfg, tp)},
    "local": lambda cfg, tp: {**blocks_attn.attn_defs(cfg, tp),
                              **blocks_attn.mlp_defs(cfg, tp)},
    "attn_moe": lambda cfg, tp: {**blocks_attn.attn_defs(cfg, tp),
                                 **blocks_moe.moe_defs(cfg, tp)},
    "mamba": lambda cfg, tp: blocks_ssm.mamba_defs(cfg, tp),
    "mamba_mlp": lambda cfg, tp: {**blocks_ssm.mamba_defs(cfg, tp),
                                  **blocks_attn.mlp_defs(cfg, tp)},
    "mamba_moe": lambda cfg, tp: {**blocks_ssm.mamba_defs(cfg, tp),
                                  **blocks_moe.moe_defs(cfg, tp)},
    "mlstm": lambda cfg, tp: blocks_rnn.mlstm_defs(cfg, tp),
    "slstm": lambda cfg, tp: blocks_rnn.slstm_defs(cfg, tp),
    "rwkv": lambda cfg, tp: blocks_rnn.rwkv_defs(cfg, tp),
}


# ---------------------------------------------------------------------------
# parameter definitions for a whole model
# ---------------------------------------------------------------------------


def model_defs(cfg: ModelConfig, tp: int):
    D = cfg.d_model
    Vp = cfg.vocab_padded(tp)
    defs: dict[str, Any] = {
        "embed": pdef(Vp, D, tp=0, fsdp=1, init="embed"),
        "final_ln": pdef(D, init="zeros"),
        "sp_embed": spike_pdefs(D),
        "sp_head": spike_pdefs(D),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = pdef(D, Vp, tp=1, fsdp=0)

    unit = {}
    for i, kind in enumerate(cfg.pattern):
        unit[f"pos{i}"] = BLOCK_DEFS[kind](cfg, tp)
    defs["units"] = stack_defs(unit, cfg.n_units)

    if cfg.is_encdec:
        assert cfg.n_enc_layers > 0
        enc_unit = {"pos0": BLOCK_DEFS["attn"](cfg, tp)}
        defs["enc_units"] = stack_defs(enc_unit, cfg.n_enc_layers)
        # decoder cross-attention per decoder unit position
        cross_unit = {}
        for i, _ in enumerate(cfg.pattern):
            cross_unit[f"pos{i}"] = blocks_attn.attn_defs(cfg, tp, cross=True)
        defs["cross_units"] = stack_defs(cross_unit, cfg.n_units)
        defs["sp_enc_out"] = spike_pdefs(D)
    return defs


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(p, tokens_loc, ctx: Context):
    """tokens_loc [B_loc, S_loc] -> x [B_loc, S_loc, D] (seq-sharded).

    Vocab is tp-sharded; each rank embeds from its shard and the partials
    are summed+scattered back to the seq domain.  Exactly one rank
    contributes per token, so the wire is naturally sparse — a boundary.
    """
    cfg = ctx.cfg
    tp = ctx.tp_size
    Vp = cfg.vocab_padded(tp)
    V_loc = Vp // tp
    if tp == 1:
        emb = fsdp_gather(p["embed"], ctx, 1)
        return jnp.take(emb, tokens_loc, axis=0)
    ids = lax.all_gather(tokens_loc, ctx.tp, axis=1, tiled=True)  # [B,S]
    emb = fsdp_gather(p["embed"], ctx, 1)                         # [V_loc, D]
    r = lax.axis_index(ctx.tp)
    off = r * V_loc
    loc = jnp.clip(ids - off, 0, V_loc - 1)
    part = jnp.take(emb, loc, axis=0)                             # [B,S,D]
    valid = ((ids >= off) & (ids < off + V_loc))[..., None]
    part = jnp.where(valid, part, 0).astype(cfg.dtype)
    return boundary.coded_psum_scatter(part, p["sp_embed"], ctx.codec,
                                       ctx.tp, axis=1)


def lm_logits_local(p, x_loc, ctx: Context):
    """x_loc [B,S_loc,D] -> (logits [B,S,V_loc] for the full seq, pen)."""
    cfg = ctx.cfg
    h = common.norm(x_loc, p["final_ln"], cfg.norm)
    if ctx.tp_size == 1:
        head = _head_w(p, ctx)
        return (h @ head).astype(F32), jnp.zeros((), F32)
    pen, _ = blocks_attn._stats(h, p["sp_head"], ctx)
    xg = boundary.coded_all_gather(h, p["sp_head"], ctx.codec, ctx.tp,
                                   axis=1)
    head = _head_w(p, ctx)                                        # [D, V_loc]
    logits = (xg @ head).astype(F32)
    return logits, pen


def _head_w(p, ctx):
    cfg = ctx.cfg
    if cfg.tie_embeddings:
        emb = fsdp_gather(p["embed"], ctx, 1)                     # [V_loc, D]
        return emb.T.astype(cfg.dtype)
    return fsdp_gather(p["lm_head"], ctx, 0)


def lm_loss_chunked(p, x_loc, labels_loc, ctx: Context, mask=None,
                    chunk: int = 512):
    """Fused final-norm -> gather -> head matmul -> distributed XE,
    scanned over seq chunks so the [B, S, V_loc] logits tensor never
    materializes (the single largest activation at 150k-vocab scale).

    Returns (mean NLL, boundary penalty).
    """
    cfg = ctx.cfg
    tp = ctx.tp_size
    h = common.norm(x_loc, p["final_ln"], cfg.norm)
    if tp == 1:
        logits = (h @ _head_w(p, ctx)).astype(F32)
        if cfg.final_softcap:
            logits = common.softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, labels_loc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            return (jnp.sum(nll * mask)
                    / jnp.maximum(jnp.sum(mask), 1)), jnp.zeros((), F32)
        return jnp.mean(nll), jnp.zeros((), F32)

    pen, _ = blocks_attn._stats(h, p["sp_head"], ctx)
    xg = boundary.coded_all_gather(h, p["sp_head"], ctx.codec, ctx.tp,
                                   axis=1)
    labels = lax.all_gather(labels_loc, ctx.tp, axis=1, tiled=True)
    mask_g = None
    if mask is not None:
        mask_g = lax.all_gather(mask, ctx.tp, axis=1, tiled=True)
    head = _head_w(p, ctx)                                    # [D, V_loc]
    V_loc = head.shape[1]
    r = lax.axis_index(ctx.tp)
    off = r * V_loc
    B, S, D = xg.shape
    qc = min(chunk, S)
    nc = S // qc

    def chunk_nll(xg_c, lab_c):
        logits = (xg_c @ head).astype(F32)                    # [B,qc,V_loc]
        if cfg.final_softcap:
            logits = common.softcap(logits, cfg.final_softcap)
        m_loc = jnp.max(logits, axis=-1)
        m = lax.stop_gradient(lax.pmax(lax.stop_gradient(m_loc), ctx.tp))
        se = lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), -1), ctx.tp)
        lse = m + jnp.log(se)
        loc = jnp.clip(lab_c - off, 0, V_loc - 1)
        gold_p = jnp.take_along_axis(logits, loc[..., None], -1)[..., 0]
        valid = (lab_c >= off) & (lab_c < off + V_loc)
        gold = lax.psum(jnp.where(valid, gold_p, 0.0), ctx.tp)
        return lse - gold                                     # [B,qc]

    if ctx.mode == "train":
        chunk_nll = jax.checkpoint(chunk_nll, prevent_cse=False)

    def body(acc, i):
        xg_c = lax.dynamic_slice_in_dim(xg, i * qc, qc, axis=1)
        lab_c = lax.dynamic_slice_in_dim(labels, i * qc, qc, axis=1)
        nll = chunk_nll(xg_c, lab_c)
        if mask_g is not None:
            mk = lax.dynamic_slice_in_dim(mask_g, i * qc, qc, axis=1)
            return (acc[0] + jnp.sum(nll * mk), acc[1] + jnp.sum(mk)), None
        return (acc[0] + jnp.sum(nll), acc[1] + nll.size), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), F32),
                                    jnp.zeros((), F32)), jnp.arange(nc))
    return tot / jnp.maximum(cnt, 1), pen


def xent_loss(logits_loc, labels_loc, ctx: Context, mask=None):
    """Cross-entropy over a tp-sharded vocab.

    logits_loc [B, S, V_loc] (full seq, local vocab shard);
    labels_loc [B, S_loc] (seq-sharded) -> scalar mean NLL over tokens.
    """
    cfg = ctx.cfg
    tp = ctx.tp_size
    if cfg.final_softcap:
        logits_loc = common.softcap(logits_loc, cfg.final_softcap)
    if tp == 1:
        lse = jax.nn.logsumexp(logits_loc, axis=-1)
        gold = jnp.take_along_axis(
            logits_loc, labels_loc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
        return jnp.mean(nll)

    labels = lax.all_gather(labels_loc, ctx.tp, axis=1, tiled=True)  # [B,S]
    V_loc = logits_loc.shape[-1]
    r = lax.axis_index(ctx.tp)
    off = r * V_loc
    # distributed logsumexp over vocab shards (detached max: pmax has no
    # diff rule, and the max shift is gradient-free anyway)
    m_loc = jnp.max(logits_loc, axis=-1)
    m = lax.stop_gradient(lax.pmax(lax.stop_gradient(m_loc), ctx.tp))
    se = jnp.sum(jnp.exp(logits_loc - m[..., None]), axis=-1)
    se = lax.psum(se, ctx.tp)
    lse = m + jnp.log(se)
    # gold logit lives on exactly one shard
    loc = jnp.clip(labels - off, 0, V_loc - 1)
    gold_part = jnp.take_along_axis(logits_loc, loc[..., None], -1)[..., 0]
    valid = (labels >= off) & (labels < off + V_loc)
    gold = lax.psum(jnp.where(valid, gold_part, 0.0), ctx.tp)
    nll = lse - gold                                              # [B,S]
    if mask is not None:
        mask_g = lax.all_gather(mask, ctx.tp, axis=1, tiled=True)
        return jnp.sum(nll * mask_g) / jnp.maximum(jnp.sum(mask_g), 1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# block dispatch
# ---------------------------------------------------------------------------


def _ckpt(fn, ctx):
    """Per-block rematerialization: the unit backward then recomputes one
    block at a time instead of holding all blocks' residuals live."""
    if ctx.mode != "train":
        return fn
    return jax.checkpoint(fn, prevent_cse=False)


def _unit_fwd(unit_p, cross_p, x, ctx: Context, aux):
    """One scanned unit (train/prefill): run every pattern position."""
    cfg = ctx.cfg
    pen = jnp.zeros((), F32)
    occ = jnp.zeros((), F32)
    n = 0
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        p = unit_p[f"pos{i}"]
        cache_i = {}
        if kind in ("attn", "global", "local", "attn_moe"):
            x, kv, pe, oc = _ckpt(
                lambda p_, x_, aux_: blocks_attn.attn_fwd(
                    p_, x_, ctx, aux_, kind=kind), ctx)(p, x, aux)
            if kv is not None:
                cache_i["kv"] = kv
            pen, occ, n = pen + pe, occ + oc, n + 1
            if cross_p is not None:
                xp = cross_p[f"pos{i}"]
                x, ckv, pe, oc = _ckpt(
                    lambda p_, x_, aux_: blocks_attn.attn_fwd(
                        p_, x_, ctx, aux_, kind="attn", prefix="x_"),
                    ctx)(xp, x, aux)
                if ckv is not None:
                    cache_i["cross_kv"] = ckv
                pen, occ, n = pen + pe, occ + oc, n + 1
            if kind == "attn_moe":
                x, pe, oc = _ckpt(
                    lambda p_, x_, aux_: blocks_moe.moe_fwd(
                        p_, x_, ctx, aux_), ctx)(p, x, aux)
            else:
                x, pe, oc = _ckpt(
                    lambda p_, x_, aux_: blocks_attn.mlp_fwd(
                        p_, x_, ctx, aux_), ctx)(p, x, aux)
            pen, occ, n = pen + pe, occ + oc, n + 1
        elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
            x, st, pe, oc = _ckpt(
                lambda p_, x_, aux_: blocks_ssm.mamba_fwd(
                    p_, x_, ctx, aux_), ctx)(p, x, aux)
            if st is not None:
                cache_i["ssm_state"] = st
            pen, occ, n = pen + pe, occ + oc, n + 1
            if kind == "mamba_moe":
                x, pe, oc = _ckpt(
                    lambda p_, x_, aux_: blocks_moe.moe_fwd(
                        p_, x_, ctx, aux_), ctx)(p, x, aux)
                pen, occ, n = pen + pe, occ + oc, n + 1
            elif kind == "mamba_mlp":
                x, pe, oc = _ckpt(
                    lambda p_, x_, aux_: blocks_attn.mlp_fwd(
                        p_, x_, ctx, aux_), ctx)(p, x, aux)
                pen, occ, n = pen + pe, occ + oc, n + 1
        elif kind == "mlstm":
            x, st, pe, oc = _ckpt(
                lambda p_, x_, aux_: blocks_rnn.mlstm_fwd(
                    p_, x_, ctx, aux_), ctx)(p, x, aux)
            if st is not None:
                cache_i["rnn_state"] = st
            pen, occ, n = pen + pe, occ + oc, n + 1
        elif kind == "slstm":
            x, st, pe, oc = _ckpt(
                lambda p_, x_, aux_: blocks_rnn.slstm_fwd(
                    p_, x_, ctx, aux_), ctx)(p, x, aux)
            if st is not None:
                cache_i["rnn_state"] = st
            pen, occ, n = pen + pe, occ + oc, n + 1
        elif kind == "rwkv":
            x, st, pe, oc = _ckpt(
                lambda p_, x_, aux_: blocks_rnn.rwkv_fwd(
                    p_, x_, ctx, aux_), ctx)(p, x, aux)
            if st is not None:
                cache_i["rwkv_state"] = st
            pen, occ, n = pen + pe, occ + oc, n + 1
        else:
            raise ValueError(kind)
        caches[f"pos{i}"] = cache_i
    return x, caches, pen, occ / max(n, 1)


def _unit_decode(unit_p, cross_p, x, cache_u, pos, ctx: Context, aux):
    cfg = ctx.cfg
    new_cache = {}
    for i, kind in enumerate(cfg.pattern):
        p = unit_p[f"pos{i}"]
        c_i = cache_u[f"pos{i}"]
        nc_i = {}
        if kind in ("attn", "global", "local", "attn_moe"):
            x, kv = blocks_attn.attn_decode_fwd(
                p, x, c_i["kv"], pos, ctx, aux, kind=kind)
            nc_i["kv"] = kv
            if cross_p is not None:
                xp = cross_p[f"pos{i}"]
                x, ckv = blocks_attn.attn_decode_fwd(
                    xp, x, c_i["cross_kv"], pos, ctx, aux, prefix="x_")
                nc_i["cross_kv"] = ckv
            if kind == "attn_moe":
                x, _, _ = blocks_moe.moe_fwd(p, x, ctx, aux)
            else:
                x, _, _ = blocks_attn.mlp_fwd(p, x, ctx, aux)
        elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
            x, st = blocks_ssm.mamba_decode_fwd(p, x, c_i["ssm_state"], pos,
                                                ctx, aux)
            nc_i["ssm_state"] = st
            if kind == "mamba_moe":
                x, _, _ = blocks_moe.moe_fwd(p, x, ctx, aux)
            elif kind == "mamba_mlp":
                x, _, _ = blocks_attn.mlp_fwd(p, x, ctx, aux)
        elif kind == "mlstm":
            x, st = blocks_rnn.mlstm_decode_fwd(p, x, c_i["rnn_state"], pos,
                                                ctx, aux)
            nc_i["rnn_state"] = st
        elif kind == "slstm":
            x, st = blocks_rnn.slstm_decode_fwd(p, x, c_i["rnn_state"], pos,
                                                ctx, aux)
            nc_i["rnn_state"] = st
        elif kind == "rwkv":
            x, st = blocks_rnn.rwkv_decode_fwd(p, x, c_i["rwkv_state"], pos,
                                               ctx, aux)
            nc_i["rwkv_state"] = st
        new_cache[f"pos{i}"] = nc_i
    return x, new_cache


# ---------------------------------------------------------------------------
# full forward passes (inside shard_map)
# ---------------------------------------------------------------------------


def _run_stack(params, x, ctx: Context, aux, collect_cache=False):
    cfg = ctx.cfg
    cross = params.get("cross_units")

    def body(carry, unit_slice):
        x, pen, occ = carry
        unit_p, cross_p = unit_slice
        x, caches, pe, oc = _unit_fwd(unit_p, cross_p, x, ctx, aux)
        out = caches if collect_cache else None
        return (x, pen + pe, occ + oc / cfg.n_units), out

    body = jax.checkpoint(body, prevent_cse=False)
    if cross is None:
        (x, pen, occ), caches = lax.scan(
            lambda c, u: body(c, (u, None)), (x, jnp.zeros((), F32),
                                              jnp.zeros((), F32)),
            params["units"])
    else:
        (x, pen, occ), caches = lax.scan(
            body, (x, jnp.zeros((), F32), jnp.zeros((), F32)),
            (params["units"], cross))
    return x, caches, pen, occ


def _run_encoder(params, enc_in, ctx: Context, aux):
    """Encoder stack (non-causal) over frame embeddings."""
    ctx_e = ctx.with_(is_encoder=True)
    B_loc, S_enc_loc, _ = enc_in.shape
    S_enc = S_enc_loc * ctx.tp_size
    aux = dict(aux)
    aux["positions"] = jnp.broadcast_to(jnp.arange(S_enc)[None],
                                        (B_loc, S_enc))

    def body(carry, unit_p):
        x, pen = carry
        x, _, pe, _ = _unit_fwd(unit_p, None, x, ctx_e, aux)
        return (x, pen + pe), None

    body = jax.checkpoint(body, prevent_cse=False)
    (x, pen), _ = lax.scan(body, (enc_in, jnp.zeros((), F32)),
                           params["enc_units"])
    return x, pen


def forward_loss(params, batch, ctx: Context):
    """Training forward.  batch: tokens/labels [B_loc, S_loc] (+ optional
    positions3, enc_embeds).  Returns (loss, metrics)."""
    cfg = ctx.cfg
    aux = _make_aux(batch, ctx)
    pen_total = jnp.zeros((), F32)

    if cfg.is_encdec:
        enc_x, pen_e = _run_encoder(params, batch["enc_embeds"], ctx, aux)
        pen_total += pen_e
        # boundary: encoder output crosses to the decoder partition
        enc_full = boundary.coded_all_gather(
            enc_x, params["sp_enc_out"], ctx.codec, ctx.tp, axis=1)
        aux = dict(aux)
        aux["cross_src"] = enc_full

    embed = _ckpt(lambda p_, t_: embed_tokens(p_, t_, ctx), ctx)
    x = embed(params, batch["tokens"])
    x, _, pen, occ = _run_stack(params, x, ctx, aux)
    loss_ce, pen_h = lm_loss_chunked(params, x, batch["labels"], ctx,
                                     mask=batch.get("mask"))
    pen_total = pen_total + pen + pen_h
    loss = loss_ce + pen_total
    # normalize for dp-psum of grads (see train.py)
    metrics = {"loss": loss_ce, "penalty": pen_total, "occupancy": occ}
    return loss / ctx.dp_size, metrics


def forward_prefill(params, batch, ctx: Context, last_pos=None,
                    return_hidden=False):
    """Prefill: fill caches, return last-token logits + caches.

    ``last_pos`` (optional, scalar or [B] int32): per-sequence index of
    the last *real* prompt token when prompts are right-padded into a
    fixed-length prefill (the serving engine's admit path).  Defaults to
    the final position.  When set, the selected hidden crosses the wire
    through the sp_head codec so its logits match the decode path.

    ``return_hidden``: also return the selected last hidden [B, D]
    (post-wire, tp-replicated) for the learned draft heads.
    """
    cfg = ctx.cfg
    ctx = ctx.with_(mode="prefill")
    aux = _make_aux(batch, ctx)
    if cfg.is_encdec:
        enc_x, _ = _run_encoder(params, batch["enc_embeds"], ctx, aux)
        enc_full = boundary.coded_all_gather(
            enc_x, params["sp_enc_out"], ctx.codec, ctx.tp, axis=1)
        aux = dict(aux)
        aux["cross_src"] = enc_full
    x = embed_tokens(params, batch["tokens"], ctx)
    x, caches, _, _ = _run_stack(params, x, ctx, aux, collect_cache=True)
    # only the last position's logits are needed: slice before the head
    # matmul so the [B, S, V] logits tensor never exists
    last = common.norm(x, params["final_ln"], cfg.norm)
    B, S_loc, _ = last.shape
    if last_pos is not None:
        lp = jnp.broadcast_to(jnp.asarray(last_pos, jnp.int32).reshape(-1),
                              (B,))
        lidx = lp % S_loc
        cand = jnp.take_along_axis(last, lidx[:, None, None], axis=1)[:, 0]
        if ctx.tp_size > 1:
            r = lax.axis_index(ctx.tp)
            own = (lp // S_loc == r)[:, None]
            part = jnp.where(own, cand, 0).astype(cfg.dtype)
            # only the owning rank contributes: the coded psum reduces to
            # the sp_head wire roundtrip the decode path applies
            xg_last = boundary.coded_psum(part, params["sp_head"],
                                          ctx.codec, ctx.tp)
        else:
            xg_last = cand
    elif ctx.tp_size > 1:
        # global last token lives on the last tp rank's local tail
        alll = lax.all_gather(last[:, -1], ctx.tp, axis=1)   # [B, tp, D]
        xg_last = alll[:, -1]
    else:
        xg_last = last[:, -1]
    logits = (xg_last @ _head_w(params, ctx)).astype(F32)
    if cfg.final_softcap:
        logits = common.softcap(logits, cfg.final_softcap)
    if return_hidden:
        return logits, caches, xg_last
    return logits, caches


def forward_decode(params, cache, token, pos, ctx: Context, aux_extra=None):
    """One decode step.  token [B_loc] int32; pos scalar int32 or
    [B_loc] per-slot positions (batched serving).

    Two attention-cache layouts: dense slot-major (``cache[slot, pos]``,
    the single-request serve path) or — when ``aux_extra`` carries a
    ``"block_table"`` row per local slot — the serving engine's shared
    KV page pool, indexed ``cache[page, offset]`` through that table
    (see ``blocks_attn.attn_decode_fwd``).  Recurrent-state leaves are
    slot-major in both.  Returns (logits_local [B_loc, V_loc],
    new_cache)."""
    cfg = ctx.cfg
    ctx = ctx.with_(mode="decode")
    aux = dict(aux_extra or {})
    B = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    # embed: vocab is tp-sharded; exactly one rank contributes per token,
    # summed over the coded wire (same boundary as the train-path
    # psum_scatter, minus the seq scatter)
    emb = fsdp_gather(params["embed"], ctx, 1)
    tp = ctx.tp_size
    if tp == 1:
        x = jnp.take(emb, token, axis=0)[:, None, :]
    else:
        V_loc = cfg.vocab_padded(tp) // tp
        r = lax.axis_index(ctx.tp)
        off = r * V_loc
        loc = jnp.clip(token - off, 0, V_loc - 1)
        part = jnp.take(emb, loc, axis=0)
        valid = ((token >= off) & (token < off + V_loc))[:, None]
        part = jnp.where(valid, part, 0).astype(cfg.dtype)
        x = boundary.coded_psum(part, params["sp_embed"], ctx.codec,
                                ctx.tp)[:, None, :]
    x = x.astype(cfg.dtype)

    def body(carry, slc):
        x = carry
        unit_p, cross_p, cache_u = slc
        x, nc = _unit_decode(unit_p, cross_p, x, cache_u, pos, ctx, aux)
        return x, nc

    cross = params.get("cross_units")
    if cross is None:
        x, new_cache = lax.scan(
            lambda c, s: body(c, (s[0], None, s[1])), x,
            (params["units"], cache))
    else:
        x, new_cache = lax.scan(body, x, (params["units"], cross, cache))

    h = common.norm(x, params["final_ln"], cfg.norm)
    if ctx.tp_size > 1:
        # hidden->head die crossing: train/prefill gather h through the
        # sp_head codec, so serving applies the same wire roundtrip
        h = boundary.wire_roundtrip(h, params["sp_head"], ctx.codec)
    head = _head_w(params, ctx)
    logits = (h[:, 0] @ head).astype(F32)
    if cfg.final_softcap:
        logits = common.softcap(logits, cfg.final_softcap)
    return logits, new_cache


def _unit_verify(unit_p, x, cache_u, pos, ctx: Context, aux):
    """One scanned unit of the batched k-token verify step.

    Attention families only: recurrent blocks (ssm/rnn/rwkv) fold every
    token into their state, which cannot roll back when a draft is
    rejected — the serving engine forces ``spec_k=0`` for those.
    """
    cfg = ctx.cfg
    new_cache = {}
    for i, kind in enumerate(cfg.pattern):
        p = unit_p[f"pos{i}"]
        c_i = cache_u[f"pos{i}"]
        nc_i = {}
        if kind in ("attn", "global", "local", "attn_moe"):
            x, kv = blocks_attn.attn_verify_fwd(p, x, c_i["kv"], pos, ctx,
                                                aux, kind=kind)
            nc_i["kv"] = kv
            if kind == "attn_moe":
                x, _, _ = blocks_moe.moe_fwd(p, x, ctx, aux)
            else:
                x, _, _ = blocks_attn.mlp_fwd(p, x, ctx, aux)
        else:
            raise NotImplementedError(
                f"verify step over recurrent block {kind!r}: state cannot "
                "roll back rejected drafts (engine falls back to spec_k=0)")
        new_cache[f"pos{i}"] = nc_i
    return x, new_cache


def forward_verify(params, cache, tokens, pos, ctx: Context, aux_extra=None,
                   return_hidden=False):
    """Batched speculative-verify step: score K1 = spec_k+1 positions of
    every slot in ONE forward (the decode-boundary traffic of K1 steps
    through one set of coded collectives — the workload the spike wire
    absorbs).

    tokens [B, K1] int32 — per slot, the last committed token followed by
    spec_k draft tokens; pos [B] int32 — the base cache position of each
    slot's first token.  KV for position pos+j is written for every j
    (through ``aux_extra["block_table"]`` when the cache is the engine's
    shared page pool — the scheduler must have mapped pages covering
    pos..pos+K1-1 first); acceptance (and page-exact rollback of
    rejected positions) is the scheduler's job.
    Returns (logits_local [B, K1, V_loc], new_cache);
    logits[:, j] condition on tokens[:, :j+1] — greedy-argmax of column j
    is the verify target for draft j+1.

    ``return_hidden``: also return the final hidden [B, K1, D] AFTER the
    sp_head wire roundtrip — replicated across tp ranks, so the learned
    draft heads (``draft_heads.head_hiddens``) can read it with no new
    collective.
    """
    cfg = ctx.cfg
    ctx = ctx.with_(mode="decode")
    aux = dict(aux_extra or {})
    B, K1 = tokens.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    # same vocab-sharded embed boundary as forward_decode, K1 tokens wide
    emb = fsdp_gather(params["embed"], ctx, 1)
    tp = ctx.tp_size
    if tp == 1:
        x = jnp.take(emb, tokens, axis=0)                    # [B,K1,D]
    else:
        V_loc = cfg.vocab_padded(tp) // tp
        r = lax.axis_index(ctx.tp)
        off = r * V_loc
        loc = jnp.clip(tokens - off, 0, V_loc - 1)
        part = jnp.take(emb, loc, axis=0)
        valid = ((tokens >= off) & (tokens < off + V_loc))[..., None]
        part = jnp.where(valid, part, 0).astype(cfg.dtype)
        x = boundary.coded_psum(part, params["sp_embed"], ctx.codec, ctx.tp)
    x = x.astype(cfg.dtype)

    if params.get("cross_units") is not None:
        raise NotImplementedError("verify step: encoder-decoder unsupported")

    def body(carry, slc):
        x = carry
        unit_p, cache_u = slc
        x, nc = _unit_verify(unit_p, x, cache_u, pos, ctx, aux)
        return x, nc

    x, new_cache = lax.scan(body, x, (params["units"], cache))

    h = common.norm(x, params["final_ln"], cfg.norm)
    if ctx.tp_size > 1:
        h = boundary.wire_roundtrip(h, params["sp_head"], ctx.codec)
    head = _head_w(params, ctx)
    logits = (h @ head).astype(F32)                          # [B,K1,V_loc]
    if cfg.final_softcap:
        logits = common.softcap(logits, cfg.final_softcap)
    if return_hidden:
        return logits, new_cache, h
    return logits, new_cache


def _make_aux(batch, ctx: Context):
    cfg = ctx.cfg
    tokens = batch["tokens"]
    B_loc, S_loc = tokens.shape
    S = S_loc * ctx.tp_size
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B_loc, S))
    aux = {"positions": positions}
    if cfg.rope_kind == "mrope":
        if "positions3" in batch:
            p3_loc = batch["positions3"]
            aux["positions3"] = lax.all_gather(p3_loc, ctx.tp, axis=2,
                                               tiled=True)
        else:
            aux["positions3"] = jnp.broadcast_to(positions[None],
                                                 (3, B_loc, S))
    return aux
