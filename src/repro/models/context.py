"""Execution context threaded through every sharded block."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..core.boundary import BoundaryCodec
from ..core.spike import SpikeConfig


def codec_from_name(name: str, hnn_mode: str) -> BoundaryCodec:
    bwd = "none"
    if name.endswith("+bwd8"):       # int8-compressed backward cotangents
        name = name[:-5]
        bwd = "int8"
    if hnn_mode == "ann" or name == "none":
        return BoundaryCodec(mode="none")
    if name == "int8":
        return BoundaryCodec(mode="int8", bwd_mode=bwd)
    if name == "spike":
        return BoundaryCodec(mode="spike", cfg=SpikeConfig(T=15,
                                                           faithful=True),
                             bwd_mode=bwd)
    if name == "spike_fused":
        return BoundaryCodec(mode="spike_fused", cfg=SpikeConfig(T=15),
                             bwd_mode=bwd)
    if name == "spike_pack4":
        return BoundaryCodec(mode="spike_pack4", cfg=SpikeConfig(T=7),
                             bwd_mode=bwd)
    if name == "sparse_topk":
        return BoundaryCodec(mode="sparse_topk", cfg=SpikeConfig(T=15),
                             capacity=0.125, bwd_mode=bwd)
    raise ValueError(name)


@dataclasses.dataclass(frozen=True)
class Context:
    cfg: ModelConfig
    dp: Tuple[str, ...]            # FSDP/data axes, e.g. ("pod","data")
    tp: str                        # tensor axis name
    dp_size: int
    tp_size: int
    codec: BoundaryCodec
    mode: str = "train"            # train|prefill|decode
    cp: Tuple[str, ...] = ()       # decode context-parallel axes (incl tp)
    collect_stats: bool = True
    is_encoder: bool = False       # non-causal attention

    @property
    def dp_axes(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def with_(self, **kw):
        return dataclasses.replace(self, **kw)


def fsdp_gather(w, ctx: Context, dim: int):
    """Gather an FSDP-sharded weight along ``dim`` (ZeRO-3 forward gather;
    AD transposes this to a grad reduce-scatter)."""
    if ctx.dp_size == 1:
        return w
    return lax.all_gather(w, ctx.dp_axes, axis=dim, tiled=True)


def cp_linear_index(ctx: Context):
    """Linearized shard index over the context-parallel axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in ctx.cp:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def cp_size(ctx: Context) -> int:
    """Static size of the context-parallel axes (inside shard_map)."""
    n = 1
    for a in ctx.cp:
        n *= lax.axis_size(a)
    return n


def axes_linear_index(axes):
    """Linearized (major-to-minor) shard index over named mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def pool_linear_index(ctx: Context):
    """Linearized shard index over the KV page-pool axes (dp x tp).

    The serving page pool shards its page dim over ALL mesh axes: the
    allocator draws a slot's pages from the slot's own dp group's
    contiguous page range, so pages-over-(dp, tp) keeps reads/writes
    local to the owning dp group while the pool's HBM footprint still
    splits across every device.  Always iterates the mesh axis names
    (not ``ctx.dp_size``, which ``replicate_weights`` rewrites to 1 to
    disable FSDP gathers — the pool stays sharded regardless).
    """
    return axes_linear_index((*ctx.dp, ctx.tp))


def pool_local_pages(page_ids, pool_index, pages_local):
    """Map global KV-pool page ids onto THIS shard's local pool slice.

    The single source of truth for the page-id -> shard-local-index
    contract (global page p lives on shard ``p // pages_local`` at row
    ``p % pages_local``); every pool reader/writer (decode/verify
    gather+scatter, admit insert) must come through here so a layout
    change cannot desynchronize them.  Returns ``(loc, ok)``: where
    ``ok`` (mapped and resident here), ``loc`` is the local row; else
    ``loc`` is ``pages_local`` — one past the end, so scatters with
    ``mode="drop"`` discard it and gathers clamp it with
    ``jnp.minimum(loc, pages_local - 1)`` + mask on ``ok``.
    """
    loc = page_ids - pool_index * pages_local
    ok = (page_ids >= 0) & (loc >= 0) & (loc < pages_local)
    return jnp.where(ok, loc, pages_local), ok
