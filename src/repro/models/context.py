"""Execution context threaded through every sharded block."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..core.boundary import BoundaryCodec
from ..core.spike import SpikeConfig


def codec_from_name(name: str, hnn_mode: str) -> BoundaryCodec:
    bwd = "none"
    if name.endswith("+bwd8"):       # int8-compressed backward cotangents
        name = name[:-5]
        bwd = "int8"
    if hnn_mode == "ann" or name == "none":
        return BoundaryCodec(mode="none")
    if name == "int8":
        return BoundaryCodec(mode="int8", bwd_mode=bwd)
    if name == "spike":
        return BoundaryCodec(mode="spike", cfg=SpikeConfig(T=15,
                                                           faithful=True),
                             bwd_mode=bwd)
    if name == "spike_fused":
        return BoundaryCodec(mode="spike_fused", cfg=SpikeConfig(T=15),
                             bwd_mode=bwd)
    if name == "spike_pack4":
        return BoundaryCodec(mode="spike_pack4", cfg=SpikeConfig(T=7),
                             bwd_mode=bwd)
    if name == "sparse_topk":
        return BoundaryCodec(mode="sparse_topk", cfg=SpikeConfig(T=15),
                             capacity=0.125, bwd_mode=bwd)
    raise ValueError(name)


@dataclasses.dataclass(frozen=True)
class Context:
    cfg: ModelConfig
    dp: Tuple[str, ...]            # FSDP/data axes, e.g. ("pod","data")
    tp: str                        # tensor axis name
    dp_size: int
    tp_size: int
    codec: BoundaryCodec
    mode: str = "train"            # train|prefill|decode
    cp: Tuple[str, ...] = ()       # decode context-parallel axes (incl tp)
    collect_stats: bool = True
    is_encoder: bool = False       # non-causal attention

    @property
    def dp_axes(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def with_(self, **kw):
        return dataclasses.replace(self, **kw)


def fsdp_gather(w, ctx: Context, dim: int):
    """Gather an FSDP-sharded weight along ``dim`` (ZeRO-3 forward gather;
    AD transposes this to a grad reduce-scatter)."""
    if ctx.dp_size == 1:
        return w
    return lax.all_gather(w, ctx.dp_axes, axis=dim, tiled=True)


def cp_linear_index(ctx: Context):
    """Linearized shard index over the context-parallel axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in ctx.cp:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def cp_size(ctx: Context) -> int:
    """Static size of the context-parallel axes (inside shard_map)."""
    n = 1
    for a in ctx.cp:
        n *= lax.axis_size(a)
    return n
