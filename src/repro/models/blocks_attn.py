"""Attention + dense-MLP blocks (per-shard, spike boundaries at collectives).

Sharding (Megatron-style TP with sequence parallelism):
  activations x [B_loc, S_loc, D] — batch over dp, seq over tp;
  attention: heads over tp; MLP: d_ff over tp.
  The 4 collectives per layer (gather-in / scatter-out for attn and MLP)
  are exactly the die-to-die boundaries; they carry the spike wire.

Decode (context-parallel): KV cache seq-sharded over ctx.cp; q heads are
gathered (tiny) and each shard computes an LSE partial over its cache
slice (distributed flash-decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core import boundary
from ..kernels import ops as kops
from . import common
from .context import (Context, cp_linear_index, cp_size, fsdp_gather,
                      pool_linear_index, pool_local_pages)
from .params import pdef, spike_pdefs


# ---------------------------------------------------------------------------
# dims
# ---------------------------------------------------------------------------


def attn_dims(cfg, tp):
    dh = cfg.d_head
    Hkv = cfg.n_kv_heads
    if Hkv == cfg.n_heads:                      # MHA: pad both together
        Hq = cfg.padded(cfg.n_heads, tp)
        Hkv_p = Hq
        kv_rep = False
    else:
        Hq = cfg.padded(cfg.n_heads, tp)
        # need Hq % Hkv == 0 for grouped layout
        while Hq % Hkv != 0:
            Hq += tp
        Hkv_p = Hkv
        kv_rep = Hkv % tp != 0
    Hq_loc = Hq // tp
    Hkv_loc = Hkv_p if kv_rep else Hkv_p // tp
    return dict(dh=dh, Hq=Hq, Hq_loc=Hq_loc, Hkv=Hkv_p, Hkv_loc=Hkv_loc,
                kv_rep=kv_rep, group=Hq // Hkv_p)


# ---------------------------------------------------------------------------
# parameter defs
# ---------------------------------------------------------------------------


def attn_defs(cfg, tp, cross=False):
    d = attn_dims(cfg, tp)
    D, dh = cfg.d_model, d["dh"]
    kv_tp = None if d["kv_rep"] else 1
    defs = {
        "ln": pdef(D, init="zeros"),
        "wq": pdef(D, d["Hq"] * dh, tp=1, fsdp=0),
        "wk": pdef(D, d["Hkv"] * dh, tp=kv_tp, fsdp=0),
        "wv": pdef(D, d["Hkv"] * dh, tp=kv_tp, fsdp=0),
        "wo": pdef(d["Hq"] * dh, D, tp=0, fsdp=1),
        "sp_in": spike_pdefs(D),
        "sp_out": spike_pdefs(D),
    }
    if cfg.qkv_bias:
        defs["bq"] = pdef(d["Hq"] * dh, tp=0, init="zeros")
        defs["bk"] = pdef(d["Hkv"] * dh, tp=(None if d["kv_rep"] else 0),
                          init="zeros")
        defs["bv"] = pdef(d["Hkv"] * dh, tp=(None if d["kv_rep"] else 0),
                          init="zeros")
    if cfg.post_norm:
        defs["post_ln"] = pdef(D, init="zeros")
    if cfg.hnn_mode == "snn":
        defs["sp_snn"] = spike_pdefs(d["Hq_loc"] * dh if False else D)
    if cross:
        defs = {f"x_{k}": v for k, v in defs.items()}
    return defs


def mlp_defs(cfg, tp):
    D = cfg.d_model
    F = cfg.ff_padded(tp)
    defs = {
        "ln2": pdef(D, init="zeros"),
        "w1": pdef(D, F, tp=1, fsdp=0),
        "w3": pdef(D, F, tp=1, fsdp=0),
        "w2": pdef(F, D, tp=0, fsdp=1),
        "sp_in2": spike_pdefs(D),
        "sp_out2": spike_pdefs(D),
    }
    if cfg.post_norm:
        defs["post_ln2"] = pdef(D, init="zeros")
    if cfg.hnn_mode == "snn":
        defs["sp_snn2"] = spike_pdefs(D)
    return defs


def attn_cache_defs(cfg, tp, cp_total, B_loc, S, dtype):
    """KV cache (decode): seq-sharded over cp, full kv heads per shard."""
    d = attn_dims(cfg, tp)
    Ss = S // cp_total
    shape = (B_loc, Ss, d["Hkv"], d["dh"])
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _rope(cfg, x, aux):
    if cfg.rope_kind == "rope":
        return common.apply_rope(x, aux["positions"], cfg.rope_theta)
    if cfg.rope_kind == "mrope":
        half = x.shape[-1] // 2
        t = half - 2 * (half // 3)
        sec = (t, half // 3, half // 3)
        return common.apply_mrope(x, aux["positions3"], cfg.rope_theta, sec)
    return x


def _maybe_snn(h, p_snn, ctx):
    """SNN mode: intra-chip activations are spike-coded too."""
    if ctx.cfg.hnn_mode != "snn" or ctx.codec.mode == "none":
        return h
    return boundary._local_roundtrip(h, p_snn, ctx.codec)


def _stats(h, p, ctx):
    if ctx.mode == "train" and ctx.collect_stats:
        pen, occ = boundary.boundary_penalty(h, p, ctx.codec)
        return pen.astype(jnp.float32), occ.astype(jnp.float32)
    z = jnp.zeros((), jnp.float32)
    return z, z


# ---------------------------------------------------------------------------
# forward: train / prefill
# ---------------------------------------------------------------------------


def attn_fwd(p, x, ctx: Context, aux, kind="attn", prefix=""):
    """x [B_loc, S_loc, D] -> (x', cache_or_None, penalty, occupancy)."""
    cfg = ctx.cfg
    d = attn_dims(cfg, ctx.tp_size)
    dh = d["dh"]
    g = lambda k: p[prefix + k] if prefix else p[k]

    h = common.norm(x, g("ln"), cfg.norm)
    pen, occ = _stats(h, g("sp_in"), ctx)
    xg = boundary.coded_all_gather(h, g("sp_in"), ctx.codec, ctx.tp, axis=1)
    B, S, D = xg.shape

    wq = fsdp_gather(g("wq"), ctx, 0)
    wk = fsdp_gather(g("wk"), ctx, 0)
    wv = fsdp_gather(g("wv"), ctx, 0)

    kv_src = aux.get("cross_src") if prefix else None
    src = kv_src if kv_src is not None else xg

    q = xg @ wq
    k = src @ wk
    v = src @ wv
    if cfg.qkv_bias:
        q = q + g("bq")
        k = k + g("bk")
        v = v + g("bv")
    q = q.reshape(B, S, d["Hq_loc"], dh)
    Skv = src.shape[1]
    k = k.reshape(B, Skv, -1, dh)
    v = v.reshape(B, Skv, -1, dh)

    if kv_src is None and cfg.rope_kind != "none":
        q = _rope(cfg, q, aux)
        k = _rope(cfg, k, aux)

    if d["kv_rep"]:
        # local q heads pick their kv group from the replicated full set
        r = lax.axis_index(ctx.tp)
        gidx = (r * d["Hq_loc"] + jnp.arange(d["Hq_loc"])) // d["group"]
        k_use = jnp.take(k, gidx, axis=2)
        v_use = jnp.take(v, gidx, axis=2)
    else:
        k_use, v_use = k, v

    causal = (not ctx.is_encoder) and (kv_src is None)
    window = cfg.window if kind == "local" else 0
    out = common.flash_attention(
        q, k_use, v_use, causal=causal, window=window,
        cap=cfg.attn_softcap,
        q_chunk=min(512, S), kv_chunk=min(512, Skv))

    out = out.reshape(B, S, d["Hq_loc"] * dh)
    wo = fsdp_gather(g("wo"), ctx, 1)
    part = out @ wo
    y = boundary.coded_psum_scatter(part, g("sp_out"), ctx.codec, ctx.tp,
                                    axis=1)
    if cfg.hnn_mode == "snn":
        y = _maybe_snn(y, g("sp_snn"), ctx)
    if cfg.post_norm:
        y = common.norm(y, g("post_ln"), cfg.norm)

    cache = None
    if ctx.mode == "prefill":
        cache = _reshard_kv_for_decode(k, v, d, ctx)
    return x + y, cache, pen, occ


def _reshard_kv_for_decode(k, v, d, ctx: Context):
    """Train-layout kv (head-sharded or replicated, full seq) ->
    decode layout (seq-sharded over cp, full heads)."""
    n = cp_size(ctx)
    if d["kv_rep"]:
        # already full heads; slice local seq shard
        idx = cp_linear_index(ctx)
        Ss = k.shape[1] // n
        k_s = lax.dynamic_slice_in_dim(k, idx * Ss, Ss, axis=1)
        v_s = lax.dynamic_slice_in_dim(v, idx * Ss, Ss, axis=1)
        return {"k": k_s, "v": v_s}
    # heads sharded over tp: all_to_all seq<->heads over tp; if cp includes
    # dp axes (long-context), additionally slice seq locally.
    k2 = lax.all_to_all(k, ctx.tp, split_axis=1, concat_axis=2, tiled=True)
    v2 = lax.all_to_all(v, ctx.tp, split_axis=1, concat_axis=2, tiled=True)
    extra = n // ctx.tp_size if len(ctx.cp) > 1 else 1
    if extra > 1:
        idx = cp_linear_index(ctx) // ctx.tp_size
        Ss = k2.shape[1] // extra
        k2 = lax.dynamic_slice_in_dim(k2, idx * Ss, Ss, axis=1)
        v2 = lax.dynamic_slice_in_dim(v2, idx * Ss, Ss, axis=1)
    return {"k": k2, "v": v2}


def mlp_fwd(p, x, ctx: Context, aux):
    cfg = ctx.cfg
    h = common.norm(x, p["ln2"], cfg.norm)
    pen, occ = _stats(h, p["sp_in2"], ctx)
    if ctx.mode == "decode":
        # tokens replicated over tp; classic TP with the coded wire on
        # both hops (roundtrip in, spike-accumulated psum out)
        h = boundary.wire_roundtrip(h, p["sp_in2"], ctx.codec)
        w1 = fsdp_gather(p["w1"], ctx, 0)
        w3 = fsdp_gather(p["w3"], ctx, 0)
        w2 = fsdp_gather(p["w2"], ctx, 1)
        hh = common.act_fn(h @ w1, cfg.act) * (h @ w3)
        y = boundary.coded_psum(hh @ w2, p["sp_out2"], ctx.codec, ctx.tp)
    else:
        xg = boundary.coded_all_gather(h, p["sp_in2"], ctx.codec, ctx.tp,
                                       axis=1)
        w1 = fsdp_gather(p["w1"], ctx, 0)
        w3 = fsdp_gather(p["w3"], ctx, 0)
        w2 = fsdp_gather(p["w2"], ctx, 1)
        hh = common.act_fn(xg @ w1, cfg.act) * (xg @ w3)
        part = hh @ w2
        y = boundary.coded_psum_scatter(part, p["sp_out2"], ctx.codec,
                                        ctx.tp, axis=1)
    if cfg.hnn_mode == "snn":
        y = _maybe_snn(y, p.get("sp_snn2"), ctx)
    if cfg.post_norm:
        y = common.norm(y, p["post_ln2"], cfg.norm)
    return x + y, pen, occ


# ---------------------------------------------------------------------------
# paged KV: block-table indexed writes/gathers on the shared page pool
# ---------------------------------------------------------------------------


def _paged_kv_write(cache, bt, qpos, k_new, v_new, ctx: Context):
    """Scatter new KV rows through the block table into the local pool
    shard: ``pool[page, offset]`` with ``page = bt[slot, pos//psz]``.

    cache {k,v} [P_loc, psz, Hkv, dh] (this shard's pages of the pool);
    bt [B, PPS] int32 global page ids (-1 unmapped); qpos [B, K1]
    absolute write positions; k_new/v_new [B, K1, Hkv, dh].

    Writes whose page is unmapped, resident on another shard, or whose
    position falls past the block table (>= PPS * psz) are DROPPED via
    an out-of-bounds scatter index — never clipped into a live page.
    An evicted slot (bt row all -1) therefore cannot corrupt a page
    that was recycled to another slot, which the old slot-major layout
    got for free from slot-private rows.  Valid (page, offset) targets
    are unique across (slot, query): a slot's qpos are distinct and
    live slots' page sets are disjoint (allocator invariant), so the
    scatter needs no duplicate-resolution order.
    """
    ck, cv = cache["k"], cache["v"]
    P_loc, psz = ck.shape[0], ck.shape[1]
    PPS = bt.shape[1]
    pj = qpos // psz                                        # [B, K1]
    oj = qpos % psz
    g = jnp.take_along_axis(bt, jnp.clip(pj, 0, PPS - 1), axis=1)
    loc, _ = pool_local_pages(g, pool_linear_index(ctx), P_loc)
    # a position past the block table (>= PPS * psz) must also drop
    loc = jnp.where(pj < PPS, loc, P_loc)    # OOB index -> mode="drop"
    ck = ck.at[loc, oj].set(k_new.astype(ck.dtype), mode="drop")
    cv = cv.at[loc, oj].set(v_new.astype(cv.dtype), mode="drop")
    return {"k": ck, "v": cv}


def _paged_kv_gather(cache, bt, ctx: Context):
    """Gather every local slot's resident pages, ordered by position.

    cache {k,v} [P_loc, psz, Hkv, dh]; bt [B, PPS].  Returns
    (k [B, PPS*psz, Hkv, dh], v likewise, valid [B, PPS*psz] bool) —
    entry ``i`` of the gathered sequence IS absolute position ``i`` of
    the slot, so the attention partial runs with ``shard_offset=0`` and
    ``valid`` masks entries whose page is unmapped or lives on another
    shard (those rows carry arbitrary pool data and must never score).
    The gather spans the full block table on every shard: each shard
    materializes [B, max_seq] gathered K/V + scores where the dense
    seq-sharded layout touched only its [B, max_seq / cp] slice — a
    cp-fold per-shard overhead on the decode step, deliberately traded
    for the pooled memory layout at the small B x max_seq shapes the
    engine serves.  The fused path
    (``kernels/paged_decode.py`` + the host-built compacted per-shard
    page lists) restores the 1/cp slice; this gather stays as the
    reference oracle the fused kernel is fuzz-checked against.

    Invariant: every non-resident entry gathers LOCAL PAGE 0 — one
    fixed row, the same for all invalid entries — rather than clamping
    ``loc`` to ``P_loc - 1`` (which aliased invalid entries onto
    whatever page happened to sit last in the shard).  Page 0's
    contents never score (``ok`` masks them); pinning all dead gathers
    to a single row keeps the reference path's memory traffic honest
    for the fused-vs-reference bench comparison (one hot row instead
    of a scatter of arbitrary pool rows) and makes the gather's
    out-of-range behavior independent of pool size.
    """
    ck, cv = cache["k"], cache["v"]
    P_loc, psz, Hkv, dh = ck.shape
    B, PPS = bt.shape
    loc, ok = pool_local_pages(bt, pool_linear_index(ctx), P_loc)
    idx = jnp.where(ok, loc, 0)
    kg = ck[idx].reshape(B, PPS * psz, Hkv, dh)
    vg = cv[idx].reshape(B, PPS * psz, Hkv, dh)
    return kg, vg, jnp.repeat(ok, psz, axis=1)


def _combine_partials(o, lse, ctx: Context):
    """Cross-shard combine of a flash partial; coded wire when the codec
    is.  Mode "none" is the plain fp LSE combine; every coded mode
    quantizes the locally-normalized partial to the per-token int8 wire
    (``boundary.quantize_partial`` — bit-identical to the fused kernel's
    epilogue) and combines through ``coded_combine_partials``, so the
    decode step's last fp collective becomes int8 + fp LSE scalars."""
    if ctx.codec.mode == "none":
        return common.combine_decode_partials(o, lse, ctx.cp)
    wire, scale = boundary.quantize_partial(o)
    return boundary.coded_combine_partials(wire, scale, lse, ctx.cp,
                                           jnp.float32)


def _paged_attn_combined(q, cache, bt, page_list, qpos, ctx: Context,
                         window, cap):
    """Paged attention partial + cross-shard combine, both cache walks.

    q [B, K1, Hq, dh] (full heads, post-gather); qpos [B, K1] absolute
    query positions.  ``page_list`` (the engine's compacted per-shard
    feed, local [B, 1, ppc] after sharding — None on the reference path)
    selects the fused Pallas kernel: gather -> flash -> LSE partial in
    one pass over this shard's resident pages, with the int8 wire
    encode fused at the kernel epilogue when the codec is coded.  The
    reference path gathers the full block table (``_paged_kv_gather``)
    and scores it through ``verify_attention_partial`` — the oracle the
    fused path is fuzz-checked against.  Returns the combined
    [B, K1, Hq, dh] f32 attention output.
    """
    coded = ctx.codec.mode != "none"
    if page_list is not None:
        clp, clo = page_list
        clp, clo = clp[:, 0], clo[:, 0]            # [B_loc, ppc]
        if coded:
            wire, scale, lse = kops.paged_flash_decode(
                q, cache["k"], cache["v"], clp, clo, qpos,
                window=window, cap=cap, encode_wire=True)
            return boundary.coded_combine_partials(wire, scale, lse,
                                                   ctx.cp, jnp.float32)
        o, lse = kops.paged_flash_decode(q, cache["k"], cache["v"],
                                         clp, clo, qpos,
                                         window=window, cap=cap)
        return common.combine_decode_partials(o, lse, ctx.cp)
    k_s, v_s, kv_valid = _paged_kv_gather(cache, bt, ctx)
    o, lse = common.verify_attention_partial(
        q, k_s, v_s, pos=qpos, shard_offset=0, window=window, cap=cap,
        kv_valid=kv_valid)
    return _combine_partials(o, lse, ctx)


# ---------------------------------------------------------------------------
# forward: decode (one token, context-parallel KV)
# ---------------------------------------------------------------------------


def attn_decode_fwd(p, x, cache, pos, ctx: Context, aux, kind="attn",
                    prefix=""):
    """x [B_loc, 1, D] replicated over tp; pos scalar or [B_loc] per-slot
    positions.  Two cache layouts, selected by ``aux["block_table"]``:

      dense (single-request serve path): cache {k,v} [B_loc, Ss, Hkv, dh]
        seq-sharded over ctx.cp, indexed ``cache[slot, pos]``;
      paged (serving engine): cache {k,v} [P_loc, psz, Hkv, dh] — this
        shard's pages of the shared pool — indexed ``cache[page, offset]``
        through the per-slot block table rows in ``aux["block_table"]``.

    Returns (x', cache')."""
    cfg = ctx.cfg
    d = attn_dims(cfg, ctx.tp_size)
    dh = d["dh"]
    g = lambda k: p[prefix + k] if prefix else p[k]
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))

    h = common.norm(x, g("ln"), cfg.norm)
    # block input crosses the die boundary (train/prefill gather it); the
    # decode activation is replicated so the hop is a local roundtrip
    h = boundary.wire_roundtrip(h, g("sp_in"), ctx.codec)
    wq = fsdp_gather(g("wq"), ctx, 0)
    q = h @ wq                                      # [B,1,Hq_loc*dh]
    if cfg.qkv_bias:
        q = q + g("bq")
    q = q.reshape(B, 1, d["Hq_loc"], dh)

    is_cross = prefix != ""
    if not is_cross:
        wk = fsdp_gather(g("wk"), ctx, 0)
        wv = fsdp_gather(g("wv"), ctx, 0)
        k_new = h @ wk
        v_new = h @ wv
        if cfg.qkv_bias:
            k_new = k_new + g("bk")
            v_new = v_new + g("bv")
        k_new = k_new.reshape(B, 1, d["Hkv_loc"], dh)
        v_new = v_new.reshape(B, 1, d["Hkv_loc"], dh)
        if cfg.rope_kind != "none":
            aux_d = dict(aux)
            aux_d["positions"] = pos[:, None]                     # [B,1]
            if cfg.rope_kind == "mrope":
                aux_d["positions3"] = jnp.broadcast_to(
                    pos[None, :, None], (3, B, 1))
            q = _rope(cfg, q, aux_d)
            k_new = _rope(cfg, k_new, aux_d)
        # full q heads / kv heads on every rank — a head-space die
        # boundary, so the gather wire is coded like every other decode
        # collective (int8 per-token absmax; fp only for mode "none")
        if ctx.tp_size > 1:
            q = boundary.coded_head_all_gather(q, ctx.codec, ctx.tp,
                                               axis=2)
        if not d["kv_rep"] and ctx.tp_size > 1:
            k_new = boundary.coded_head_all_gather(k_new, ctx.codec,
                                                   ctx.tp, axis=2)
            v_new = boundary.coded_head_all_gather(v_new, ctx.codec,
                                                   ctx.tp, axis=2)
        bt = aux.get("block_table")
        if bt is not None:
            # paged: route the write through the slot's block-table row
            cache = _paged_kv_write(cache, bt, pos[:, None], k_new, v_new,
                                    ctx)
        else:
            # dense per-slot cache write: each slot lands at its own
            # position, only on the cp shard that owns it
            Ss = cache["k"].shape[1]
            off = cp_linear_index(ctx) * Ss
            in_range = (pos >= off) & (pos < off + Ss)           # [B]
            loc = jnp.clip(pos - off, 0, Ss - 1)                 # [B]
            bidx = jnp.arange(B)
            k_cur = cache["k"][bidx, loc]                        # [B,Hkv,dh]
            v_cur = cache["v"][bidx, loc]
            sel = in_range[:, None, None]
            k_w = jnp.where(sel, k_new[:, 0].astype(cache["k"].dtype), k_cur)
            v_w = jnp.where(sel, v_new[:, 0].astype(cache["v"].dtype), v_cur)
            cache = {"k": cache["k"].at[bidx, loc].set(k_w),
                     "v": cache["v"].at[bidx, loc].set(v_w)}
    else:
        if ctx.tp_size > 1:
            q = boundary.coded_head_all_gather(q, ctx.codec, ctx.tp,
                                               axis=2)

    window = cfg.window if kind == "local" else 0
    bt = None if is_cross else aux.get("block_table")
    if bt is not None:
        # paged: fused kernel over the compacted page lists when the
        # engine feeds them, else the reference full-table gather
        plist = aux.get("page_list")
        o = _paged_attn_combined(q, cache, bt, plist, pos[:, None], ctx,
                                 window, cfg.attn_softcap)[:, 0]
    else:
        k_s, v_s, kv_valid = cache["k"], cache["v"], None
        off = cp_linear_index(ctx) * cache["k"].shape[1]
        eff_pos = pos if not is_cross else jnp.full((B,), 10 ** 9,
                                                    jnp.int32)
        o, lse = common.decode_attention_partial(
            q[:, 0], k_s, v_s, pos=eff_pos, shard_offset=off,
            window=window, cap=cfg.attn_softcap, kv_valid=kv_valid)
        o = _combine_partials(o, lse, ctx)

    # output projection: local head slice, psum over tp
    r = lax.axis_index(ctx.tp)
    o_loc = lax.dynamic_slice_in_dim(o, r * d["Hq_loc"], d["Hq_loc"], axis=1)
    wo = fsdp_gather(g("wo"), ctx, 1)
    part = o_loc.reshape(B, 1, d["Hq_loc"] * dh).astype(x.dtype) @ wo
    y = boundary.coded_psum(part, g("sp_out"), ctx.codec, ctx.tp)
    if cfg.post_norm:
        y = common.norm(y, g("post_ln"), cfg.norm)
    return x + y, cache


# ---------------------------------------------------------------------------
# forward: speculative verify (K1 = spec_k+1 tokens, context-parallel KV)
# ---------------------------------------------------------------------------


def attn_verify_fwd(p, x, cache, pos, ctx: Context, aux, kind="attn"):
    """Batched k-token verify: x [B, K1, D] replicated over tp — the last
    committed token followed by spec_k draft tokens per slot; pos [B]
    per-slot *base* positions (query j sits at pos+j).  Cache layout is
    dense ([B, Ss, Hkv, dh] seq-sharded over ctx.cp) or the shared page
    pool ([P_loc, psz, Hkv, dh] + ``aux["block_table"]``), exactly as in
    ``attn_decode_fwd``.

    Every per-token op is shared with ``attn_decode_fwd`` (same norms,
    same ``wire_roundtrip`` spike boundary, same projections), so under
    greedy decoding the verify logits at position j with an all-correct
    draft prefix are bit-identical to j vanilla decode steps.  KV for
    all K1 positions lands in the cache before attention; rejected-draft
    entries are dead by masking (never attended: the committed position
    stays behind them) and are overwritten by the next verify window.
    Returns (x', cache')."""
    cfg = ctx.cfg
    d = attn_dims(cfg, ctx.tp_size)
    dh = d["dh"]
    B, K1, _ = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    qpos = pos[:, None] + jnp.arange(K1, dtype=jnp.int32)[None, :]  # [B,K1]

    h = common.norm(x, p["ln"], cfg.norm)
    h = boundary.wire_roundtrip(h, p["sp_in"], ctx.codec)
    wq = fsdp_gather(p["wq"], ctx, 0)
    q = h @ wq                                      # [B,K1,Hq_loc*dh]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, K1, d["Hq_loc"], dh)

    wk = fsdp_gather(p["wk"], ctx, 0)
    wv = fsdp_gather(p["wv"], ctx, 0)
    k_new = h @ wk
    v_new = h @ wv
    if cfg.qkv_bias:
        k_new = k_new + p["bk"]
        v_new = v_new + p["bv"]
    k_new = k_new.reshape(B, K1, d["Hkv_loc"], dh)
    v_new = v_new.reshape(B, K1, d["Hkv_loc"], dh)
    if cfg.rope_kind != "none":
        aux_d = dict(aux)
        aux_d["positions"] = qpos
        if cfg.rope_kind == "mrope":
            aux_d["positions3"] = jnp.broadcast_to(qpos[None], (3, B, K1))
        q = _rope(cfg, q, aux_d)
        k_new = _rope(cfg, k_new, aux_d)
    if ctx.tp_size > 1:
        q = boundary.coded_head_all_gather(q, ctx.codec, ctx.tp, axis=2)
    if not d["kv_rep"] and ctx.tp_size > 1:
        k_new = boundary.coded_head_all_gather(k_new, ctx.codec, ctx.tp,
                                               axis=2)
        v_new = boundary.coded_head_all_gather(v_new, ctx.codec, ctx.tp,
                                               axis=2)

    bt = aux.get("block_table")
    window = cfg.window if kind == "local" else 0
    if bt is not None:
        # paged: one duplicate-free scatter for all K1 positions (their
        # (page, offset) targets are distinct by construction), then
        # attend over the slot's resident pages — fused kernel when the
        # engine feeds the compacted lists, reference gather otherwise
        cache = _paged_kv_write(cache, bt, qpos, k_new, v_new, ctx)
        o = _paged_attn_combined(q, cache, bt, aux.get("page_list"),
                                 qpos, ctx, window, cfg.attn_softcap)
    else:
        # dense: scatter the K1 new KV rows one position at a time (K1
        # is static and small) — sequential writes keep the update
        # duplicate-free when out-of-range clips collide with in-range
        # positions
        Ss = cache["k"].shape[1]
        off = cp_linear_index(ctx) * Ss
        bidx = jnp.arange(B)
        ck, cv = cache["k"], cache["v"]
        for j in range(K1):
            pj = qpos[:, j]
            in_range = (pj >= off) & (pj < off + Ss)
            loc = jnp.clip(pj - off, 0, Ss - 1)
            sel = in_range[:, None, None]
            k_w = jnp.where(sel, k_new[:, j].astype(ck.dtype), ck[bidx, loc])
            v_w = jnp.where(sel, v_new[:, j].astype(cv.dtype), cv[bidx, loc])
            ck = ck.at[bidx, loc].set(k_w)
            cv = cv.at[bidx, loc].set(v_w)
        cache = {"k": ck, "v": cv}
        o, lse = common.verify_attention_partial(
            q, cache["k"], cache["v"], pos=qpos, shard_offset=off,
            window=window, cap=cfg.attn_softcap, kv_valid=None)
        o = _combine_partials(o, lse, ctx)

    r = lax.axis_index(ctx.tp)
    o_loc = lax.dynamic_slice_in_dim(o, r * d["Hq_loc"], d["Hq_loc"], axis=2)
    wo = fsdp_gather(p["wo"], ctx, 1)
    part = o_loc.reshape(B, K1, d["Hq_loc"] * dh).astype(x.dtype) @ wo
    y = boundary.coded_psum(part, p["sp_out"], ctx.codec, ctx.tp)
    if cfg.post_norm:
        y = common.norm(y, p["post_ln"], cfg.norm)
    return x + y, cache
