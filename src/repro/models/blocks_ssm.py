"""Mamba (selective SSM) mixer block — jamba's dominant layer type.

TP shards the inner dim d_inner over the model axis; the seq gather /
partial-sum scatter around the block are the spike boundaries (same
pattern as attention).  The selective scan runs chunked: lax.scan over
seq chunks carrying the SSM state, with an associative scan inside each
chunk — decay/drive tensors [B, chunk, Di_loc, N] are materialized one
chunk at a time so 32k prefill stays in memory.

Decode is a single state update (O(1) in sequence length — this is why
jamba runs the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core import boundary
from . import common
from .context import Context, fsdp_gather
from .params import pdef, spike_pdefs

F32 = jnp.float32


def ssm_dims(cfg, tp):
    Di = cfg.inner_padded(tp)
    return dict(Di=Di, Di_loc=Di // tp, N=cfg.d_state, R=cfg.dt_rank_eff,
                K=cfg.d_conv)


def mamba_defs(cfg, tp):
    d = ssm_dims(cfg, tp)
    D = cfg.d_model
    return {
        "ln": pdef(D, init="zeros"),
        "wi": pdef(D, 2 * d["Di"], tp=1, fsdp=0),        # x, z
        "conv_w": pdef(d["Di"], d["K"], tp=0, scale=0.1),
        "wb": pdef(D, d["N"], scale=0.05),               # B proj (replicated)
        "wc": pdef(D, d["N"], scale=0.05),               # C proj (replicated)
        "wdt1": pdef(D, d["R"], scale=0.05),
        "wdt2": pdef(d["R"], d["Di"], tp=1, scale=0.1),
        "dt_bias": pdef(d["Di"], tp=0, init="dtbias", dtype=jnp.float32),
        "a_log": pdef(d["Di"], d["N"], tp=0, init="alog", dtype=jnp.float32),
        "d_skip": pdef(d["Di"], tp=0, init="ones", dtype=jnp.float32),
        "wo": pdef(d["Di"], D, tp=0, fsdp=1),
        "sp_in": spike_pdefs(D),
        "sp_out": spike_pdefs(D),
    }


def mamba_cache_defs(cfg, tp, B_loc, dtype):
    d = ssm_dims(cfg, tp)
    return {
        "conv": jax.ShapeDtypeStruct((B_loc, d["K"] - 1, d["Di_loc"]), dtype),
        "ssm": jax.ShapeDtypeStruct((B_loc, d["Di_loc"], d["N"]), F32),
    }


def _causal_conv(x, w):
    """x [B, S, Ci]; w [Ci, K] depthwise causal."""
    B, S, Ci = x.shape
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    rhs = w.astype(F32).T[:, None, :]                # [K, I=1, O=Ci]
    out = lax.conv_general_dilated(
        xp.astype(F32), rhs,
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=Ci)
    return out.astype(x.dtype)


def _chunked_selective_scan(x_in, dt, Bm, Cm, A, h0, chunk=256):
    """x_in, dt [B,S,Di]; Bm, Cm [B,S,N]; A [Di,N]; h0 [B,Di,N].

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    Returns (y [B,S,Di], h_final).
    """
    B, S, Di = x_in.shape
    N = A.shape[1]
    ch = min(chunk, S)
    nc = S // ch
    assert S % ch == 0

    xr = x_in.reshape(B, nc, ch, Di)
    dtr = dt.reshape(B, nc, ch, Di)
    Br = Bm.reshape(B, nc, ch, N)
    Cr = Cm.reshape(B, nc, ch, N)

    def step(h, blk):
        xb, dtb, bb, cb = blk                      # [B,ch,...]
        decay = jnp.exp(dtb[..., None] * A[None, None])        # [B,ch,Di,N]
        drive = (dtb * xb)[..., None] * bb[:, :, None, :]      # [B,ch,Di,N]

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_cum, b_cum = lax.associative_scan(comb, (decay, drive), axis=1)
        h_t = a_cum * h[:, None] + b_cum                       # [B,ch,Di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cb)
        return h_t[:, -1], y

    # remat each chunk: saves only the [B, Di, N] carry per chunk instead
    # of the [B, ch, Di, N] decay/drive residual stack
    step = jax.checkpoint(step, prevent_cse=False)
    h_fin, ys = lax.scan(
        step, h0,
        (xr.transpose(1, 0, 2, 3), dtr.transpose(1, 0, 2, 3),
         Br.transpose(1, 0, 2, 3), Cr.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, Di)
    return y, h_fin


def mamba_fwd(p, x, ctx: Context, aux):
    """Train/prefill.  x [B_loc, S_loc, D] -> (x', cache|None, pen, occ)."""
    cfg = ctx.cfg
    d = ssm_dims(cfg, ctx.tp_size)
    h = common.norm(x, p["ln"], cfg.norm)
    pen, occ = _stats(h, p["sp_in"], ctx)
    xg = boundary.coded_all_gather(h, p["sp_in"], ctx.codec, ctx.tp, axis=1)
    B, S, D = xg.shape

    wi = fsdp_gather(p["wi"], ctx, 0)
    xz = xg @ wi
    x_in, z = jnp.split(xz, 2, axis=-1)            # [B,S,Di_loc]
    x_in = common.act_fn(_causal_conv(x_in, p["conv_w"]), "silu")

    Bm = (xg.astype(F32) @ p["wb"].astype(F32))
    Cm = (xg.astype(F32) @ p["wc"].astype(F32))
    dtr = xg @ p["wdt1"].astype(xg.dtype)
    wdt2 = p["wdt2"]
    dt = jax.nn.softplus(dtr.astype(F32) @ wdt2.astype(F32)
                         + p["dt_bias"][None, None])
    A = -jnp.exp(p["a_log"])

    y, h_fin = _chunked_selective_scan(
        x_in.astype(F32), dt, Bm, Cm, A,
        jnp.zeros((B, d["Di_loc"], d["N"]), F32))
    y = y + p["d_skip"][None, None] * x_in.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)

    wo = fsdp_gather(p["wo"], ctx, 1)
    part = y @ wo
    out = boundary.coded_psum_scatter(part, p["sp_out"], ctx.codec, ctx.tp,
                                      axis=1)
    cache = None
    if ctx.mode == "prefill":
        cache = {"conv": x_in[:, S - (d["K"] - 1):, :].astype(x.dtype),
                 "ssm": h_fin}
    return x + out, cache, pen, occ


def mamba_decode_fwd(p, x, cache, pos, ctx: Context, aux):
    """One-step state update.  x [B,1,D] replicated over tp; inner dims
    sharded over tp (state shard per rank)."""
    cfg = ctx.cfg
    d = ssm_dims(cfg, ctx.tp_size)
    B = x.shape[0]
    h = common.norm(x, p["ln"], cfg.norm)[:, 0]     # [B, D]
    h = boundary.wire_roundtrip(h, p["sp_in"], ctx.codec)

    wi = fsdp_gather(p["wi"], ctx, 0)
    xz = h @ wi
    x_in, z = jnp.split(xz, 2, axis=-1)             # [B, Di_loc]

    # conv state: last K-1 inputs
    conv_hist = jnp.concatenate(
        [cache["conv"], x_in[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(F32)                      # [Di_loc, K]
    x_c = jnp.einsum("bkc,ck->bc", conv_hist.astype(F32), w)
    x_c = jax.nn.silu(x_c)
    new_conv = conv_hist[:, 1:]

    Bm = h.astype(F32) @ p["wb"].astype(F32)         # [B, N]
    Cm = h.astype(F32) @ p["wc"].astype(F32)
    dtr = h @ p["wdt1"].astype(h.dtype)
    dt = jax.nn.softplus(dtr.astype(F32) @ p["wdt2"].astype(F32)
                         + p["dt_bias"][None])
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * A[None])         # [B,Di_loc,N]
    drive = (dt * x_c)[..., None] * Bm[:, None, :]
    h_new = decay * cache["ssm"] + drive
    y = jnp.einsum("bdn,bn->bd", h_new, Cm)
    y = y + p["d_skip"][None] * x_c
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)

    wo = fsdp_gather(p["wo"], ctx, 1)
    out = boundary.coded_psum(y[:, None, :] @ wo, p["sp_out"], ctx.codec,
                              ctx.tp)
    cache = {"conv": new_conv, "ssm": h_new}
    return x + out, cache


def _stats(h, p, ctx):
    if ctx.mode == "train" and ctx.collect_stats:
        pen, occ = boundary.boundary_penalty(h, p, ctx.codec)
        return pen.astype(jnp.float32), occ.astype(jnp.float32)
    z = jnp.zeros((), jnp.float32)
    return z, z
