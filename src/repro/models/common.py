"""Per-device model math: norms, rotary embeddings, chunked attention.

Everything here is plain single-shard jnp — sharding and boundary codecs
live in ``blocks.py``.  Attention is an online-softmax ("flash") double
loop over q/kv chunks so 32k-sequence prefill never materializes an SxS
score matrix.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32

# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    h = x.astype(F32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * lax.rsqrt(var + eps)
    return (h * (1.0 + scale.astype(F32))).astype(x.dtype)


def layer_norm(x, scale, bias=None, eps=1e-5):
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * lax.rsqrt(var + eps)
    h = h * (1.0 + scale.astype(F32))
    if bias is not None:
        h = h + bias.astype(F32)
    return h.astype(x.dtype)


def norm(x, scale, kind="rmsnorm"):
    return rms_norm(x, scale) if kind == "rmsnorm" else layer_norm(x, scale)


def act_fn(x, kind="silu"):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def softcap(x, cap):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=F32) / d_head))


def apply_rope(x, positions, theta=1e4):
    """x [B, S, H, dh]; positions [B, S] (int)."""
    B, S, H, dh = x.shape
    inv = rope_freqs(dh, theta)                              # [dh/2]
    ang = positions.astype(F32)[..., None] * inv             # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta=1e4, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: head-dim/2 split into (t, h, w) sections,
    each rotated by its own position stream.  positions3 [3, B, S]."""
    B, S, H, dh = x.shape
    half = dh // 2
    assert sum(sections) == half, (sections, dh)
    inv = rope_freqs(dh, theta)                              # [half]
    angs = []
    start = 0
    for i, sec in enumerate(sections):
        p = positions3[i].astype(F32)[..., None]             # [B, S, 1]
        angs.append(p * inv[start:start + sec])
        start += sec
    ang = jnp.concatenate(angs, axis=-1)                     # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked (flash) attention — full/causal/sliding-window, GQA, softcap
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    q_chunk=512, kv_chunk=512, q_offset=0):
    """Online-softmax attention.

    q [B, Sq, Hq, dh]; k, v [B, Skv, Hkv, dh]; Hq % Hkv == 0 (GQA).
    ``window`` > 0 restricts attention to the last ``window`` positions
    (sliding window); ``cap`` applies logit soft-capping (gemma2).
    ``q_offset``: absolute position of q[0] (for decode/prefill-continue).
    Returns [B, Sq, Hq, dh].
    """
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    nq, nk = Sq // qc, Skv // kc
    assert Sq % qc == 0 and Skv % kc == 0, (Sq, qc, Skv, kc)

    # [B, nq, qc, Hq, dh] -> iterate q chunks
    qr = q.reshape(B, nq, qc, Hq, dh)

    def one_q_chunk(qi, q_blk):
        # q_blk [B, qc, Hq, dh]
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, kj):
            m, l, o = carry
            k_blk = lax.dynamic_slice_in_dim(k, kj * kc, kc, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, kj * kc, kc, axis=1)
            k_pos = kj * kc + jnp.arange(kc)
            kb = k_blk.astype(F32)
            if Hkv != Hq:
                kb = jnp.repeat(kb, g, axis=2)
            # scores [B, Hq, qc, kc]
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(F32), kb)
            s = s * scale
            s = softcap(s, cap)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            vb = v_blk.astype(F32)
            if Hkv != Hq:
                vb = jnp.repeat(vb, g, axis=2)
            o_new = o * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hq, qc), -1e30, F32)
        l0 = jnp.zeros((B, Hq, qc), F32)
        o0 = jnp.zeros((B, Hq, qc, dh), F32)
        (m, l, o), _ = lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)  # [B, qc, Hq, dh]

    # remat each q-chunk: without this the kv-scan saves per-(q,k)-pair
    # softmax residuals for backward — O(S^2) HBM, fatal at 32k
    one_q_chunk = jax.checkpoint(one_q_chunk, prevent_cse=False)
    outs = lax.map(lambda args: one_q_chunk(*args),
                   (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4)))
    # outs [nq, B, qc, Hq, dh]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, dh)
    return out.astype(q.dtype)


def decode_attention_partial(q, k_shard, v_shard, *, pos, shard_offset,
                             window=0, cap=0.0, kv_valid=None):
    """One decode step over a *shard* of the KV cache.

    q [B, Hq, dh]; k_shard/v_shard [B, Ss, Hkv, dh]; pos: current absolute
    position (scalar, or [B] per-slot positions for batched serving);
    shard_offset: absolute position of this shard's first cache slot.
    ``kv_valid`` (optional [B, Ss] bool): per-slot validity of each cache
    entry — the paged layout gathers K/V through a block table, so
    entries from pages not resident on this shard (or not mapped at all)
    must be masked out of the softmax.
    Returns (out [B, Hq, dh] — locally normalized partial, lse [B, Hq])
    for cross-shard LSE combination.

    Implemented as the K1=1 case of ``verify_attention_partial`` so the
    speculative-verify path's greedy bit-identity with vanilla decode is
    structural (one copy of the masking/softmax math), not a convention
    maintained across two functions.
    """
    B, Hq, dh = q.shape
    posb = jnp.asarray(pos)
    if posb.ndim == 0:
        posb = jnp.broadcast_to(posb, (B,))
    o, lse = verify_attention_partial(
        q[:, None], k_shard, v_shard, pos=posb[:, None],
        shard_offset=shard_offset, window=window, cap=cap,
        kv_valid=kv_valid)
    return o[:, 0], lse[:, 0]


def verify_attention_partial(q, k_shard, v_shard, *, pos, shard_offset,
                             window=0, cap=0.0, kv_valid=None):
    """K1-token speculative-verify step over a *shard* of the KV cache.

    The multi-query sibling of ``decode_attention_partial``: q carries
    K1 = spec_k+1 query tokens per slot (the last committed token plus
    the draft), each attending to cache positions <= its own absolute
    position, so one batched step scores every draft position at once.
    The per-query math (masking, online-softmax reduction order over the
    cache axis) mirrors the single-token path exactly — greedy verify
    must be bit-identical to running K1 vanilla decode steps.

    q [B, K1, Hq, dh]; k_shard/v_shard [B, Ss, Hkv, dh]; pos [B, K1]
    absolute per-query positions; shard_offset: absolute position of this
    shard's first cache slot (0 for the paged layout, whose gather is
    already position-ordered per slot); ``kv_valid`` (optional [B, Ss]
    bool) masks cache entries that are not this slot's data (unmapped /
    non-resident block-table pages).  Returns (out [B, K1, Hq, dh] —
    locally normalized partial, lse [B, K1, Hq]) for cross-shard LSE
    combination.
    """
    B, K1, Hq, dh = q.shape
    _, Ss, Hkv, _ = k_shard.shape
    g = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    kb = k_shard.astype(F32)
    vb = v_shard.astype(F32)
    if Hkv != Hq:
        kb = jnp.repeat(kb, g, axis=2)
        vb = jnp.repeat(vb, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bqhk", q.astype(F32), kb) * scale
    s = softcap(s, cap)
    k_pos = shard_offset + jnp.arange(Ss)
    posb = jnp.asarray(pos)[:, :, None, None]        # [B,K1,1,1]
    mask = k_pos[None, None, None, :] <= posb
    if window:
        mask &= (posb - k_pos[None, None, None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqhk,bkhd->bqhd", p, vb)
    o = o / jnp.maximum(l[..., None], 1e-30)        # locally normalized
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return o, lse


def combine_decode_partials(o_norm, lse, axis_names):
    """LSE-weighted combination of locally-normalized decode partials.

    out = sum_d w_d * o_d / sum_d w_d,  w_d = exp(lse_d - max_d lse_d).

    The plain-fp path (codec "none").  Coded decode steps ship the
    partials as int8 wire instead — ``core.boundary.quantize_partial``
    (or the fused paged-decode kernel's epilogue) +
    ``core.boundary.coded_combine_partials``, same math over the
    decoded wire.
    """
    m = lax.pmax(lse, axis_names)                   # [B, Hq]
    w = jnp.exp(lse - m)
    o_sum = lax.psum(o_norm * w[..., None], axis_names)
    l_sum = lax.psum(w, axis_names)
    return o_sum / jnp.maximum(l_sum[..., None], 1e-30)
