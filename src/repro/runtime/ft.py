"""Fault-tolerance runtime: restart loop, straggler watch, preemption.

``TrainLoop`` is the production driver skeleton used by
examples/train_hnn_lm.py and launch/train_cli.py:

  * checkpoint/restart — resumes from the newest committed step; the
    deterministic data pipeline replays batch k bit-exactly.
  * preemption handling — SIGTERM sets a flag; the loop checkpoints and
    exits cleanly (TPU preemption notice pattern).
  * straggler mitigation — per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with host attribution, and a
    callback can trigger re-sharding away from the slow host (on real
    fleets: feed your scheduler; here: counted + surfaced in metrics).
  * elastic scaling — on restart the checkpoint re-shards to the current
    mesh (CheckpointManager.restore(mesh=...)); nothing in the step
    function depends on absolute device count.
  * NaN/overflow guard — skips the update and counts the event (grad
    spike protection for bf16 training).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import numpy as np

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    async_ckpt: bool = True
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.1
    max_nan_skips: int = 10


class TrainLoop:
    def __init__(self, step_fn: Callable, data_source, cfg: FTConfig,
                 log_fn: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.data = data_source
        self.cfg = cfg
        self.log = log_fn
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.preempted = False
        self.straggler_events = 0
        self.nan_skips = 0
        self._ewma: Optional[float] = None
        try:
            signal.signal(signal.SIGTERM, self._on_preempt)
        except ValueError:
            pass  # not main thread (tests)

    def _on_preempt(self, *_):
        self.log("[ft] preemption signal received; will checkpoint+exit")
        self.preempted = True

    # ------------------------------------------------------------------
    def run(self, params, opt_state, n_steps: int, resume: bool = True,
            mesh=None, pspecs=None, ospecs=None):
        start = 0
        if resume and self.ckpt.latest_step() is not None:
            (params, opt_state), start = self.ckpt.restore(
                (params, opt_state),
                mesh=mesh,
                specs=(pspecs, ospecs) if mesh is not None else None)
            self.log(f"[ft] resumed from step {start}")

        metrics_hist = []
        for step in range(start, n_steps):
            batch = self.data.batch(step)
            t0 = time.time()
            new_params, new_opt, metrics = self.step_fn(
                params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            # NaN guard: skip poisoned updates
            if not np.isfinite(loss):
                self.nan_skips += 1
                self.log(f"[ft] step {step}: non-finite loss, skipping "
                         f"update ({self.nan_skips}/{self.cfg.max_nan_skips})")
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise RuntimeError("too many non-finite steps")
            else:
                params, opt_state = new_params, new_opt

            # straggler watch
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.cfg.straggler_factor * self._ewma:
                self.straggler_events += 1
                self.log(f"[ft] step {step}: straggler ({dt:.3f}s vs "
                         f"EWMA {self._ewma:.3f}s)")
            self._ewma = (1 - self.cfg.ewma_alpha) * self._ewma \
                + self.cfg.ewma_alpha * dt

            metrics_hist.append({k: float(v) for k, v in metrics.items()})

            if (step + 1) % self.cfg.ckpt_every == 0 or self.preempted:
                self.ckpt.save(step + 1, (params, opt_state),
                               blocking=not self.cfg.async_ckpt)
            if self.preempted:
                self.ckpt.wait()
                self.log(f"[ft] clean exit at step {step + 1}")
                break
        self.ckpt.wait()
        return params, opt_state, metrics_hist
