"""Fault-tolerance runtime: restart loop, straggler watch, preemption.

``TrainLoop`` is the production driver skeleton used by
examples/train_hnn_lm.py and launch/train_cli.py:

  * checkpoint/restart — resumes from the newest committed step; the
    deterministic data pipeline replays batch k bit-exactly.
  * preemption handling — SIGTERM sets a flag; the loop checkpoints and
    exits cleanly (TPU preemption notice pattern).
  * straggler mitigation — per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with host attribution, and a
    callback can trigger re-sharding away from the slow host (on real
    fleets: feed your scheduler; here: counted + surfaced in metrics).
  * elastic scaling — on restart the checkpoint re-shards to the current
    mesh (CheckpointManager.restore(mesh=...)); nothing in the step
    function depends on absolute device count.
  * NaN/overflow guard — skips the update and counts the event (grad
    spike protection for bf16 training).
  * fault injection — ``run(injector=...)`` takes anything with the
    ``repro.serving.slo.FaultInjector.next_fault()`` contract and maps
    its kinds onto the machinery above: ``preempt`` triggers the SIGTERM
    checkpoint+clean-exit path, ``replica_loss`` restores from the
    newest committed checkpoint and replays forward (the restart loop,
    without killing the process), ``suspend`` books an injected
    straggler tick into the EWMA watch.  One seeded ``FaultPlan`` thus
    drives the same fault timeline into serving (engine observer) and
    training (this loop).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import numpy as np

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    async_ckpt: bool = True
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.1
    max_nan_skips: int = 10


class TrainLoop:
    def __init__(self, step_fn: Callable, data_source, cfg: FTConfig,
                 log_fn: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.data = data_source
        self.cfg = cfg
        self.log = log_fn
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.preempted = False
        self.straggler_events = 0
        self.nan_skips = 0
        #: per-kind injected-fault tally (``run(injector=...)``)
        self.injected: dict = {}
        self._ewma: Optional[float] = None
        try:
            signal.signal(signal.SIGTERM, self._on_preempt)
        except ValueError:
            pass  # not main thread (tests)

    def _on_preempt(self, *_):
        self.log("[ft] preemption signal received; will checkpoint+exit")
        self.preempted = True

    # ------------------------------------------------------------------
    def run(self, params, opt_state, n_steps: int, resume: bool = True,
            mesh=None, pspecs=None, ospecs=None, injector=None):
        """Drive ``step_fn`` for ``n_steps`` with checkpoint/restart.

        ``injector`` (optional) is rolled once per step BEFORE the step
        runs — duck-typed on ``next_fault() -> (kind, pick)`` (see
        ``repro.serving.slo.FaultInjector``):

          ``preempt``       the scheduler's preemption notice: same path
                            as SIGTERM — checkpoint, clean exit
          ``replica_loss``  revert to the newest committed checkpoint
                            and replay from there (the deterministic
                            data pipeline makes the redone steps
                            bit-exact); with no checkpoint yet, restart
                            from the initial state at step 0
          ``suspend``       a stalled host: the step's recorded wall
                            time is inflated past the straggler
                            threshold so the EWMA watch fires

        Injected events are tallied on ``self.injected`` and, when the
        injector carries a compatible dict, on ``injector.injected``.
        """
        start = 0
        if resume and self.ckpt.latest_step() is not None:
            (params, opt_state), start = self.ckpt.restore(
                (params, opt_state),
                mesh=mesh,
                specs=(pspecs, ospecs) if mesh is not None else None)
            self.log(f"[ft] resumed from step {start}")
        restore_specs = (pspecs, ospecs) if mesh is not None else None
        if injector is not None and self.ckpt.latest_step() is None:
            # a replica-loss-tolerant run always has a base checkpoint
            # to fall back to (the live state can't serve as one: train
            # steps donate their input buffers)
            self.ckpt.save(start, (params, opt_state), blocking=True)

        def _tally(kind):
            self.injected[kind] = self.injected.get(kind, 0) + 1
            inj = getattr(injector, "injected", None)
            if isinstance(inj, dict):
                inj[kind] = inj.get(kind, 0) + 1

        metrics_hist = []
        step = start
        while step < n_steps:
            fault = None
            if injector is not None:
                fault, _ = injector.next_fault()
            if fault == "preempt":
                _tally("preempt")
                self.log(f"[ft] step {step}: injected preemption notice")
                self.preempted = True
            elif fault == "replica_loss":
                _tally("replica_loss")
                (params, opt_state), step = self.ckpt.restore(
                    (params, opt_state), mesh=mesh, specs=restore_specs)
                self.log(f"[ft] replica loss: replaying from step {step}")
                del metrics_hist[max(step - start, 0):]
                continue
            batch = self.data.batch(step)
            t0 = time.time()
            new_params, new_opt, metrics = self.step_fn(
                params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if fault == "suspend":
                _tally("suspend")
                # a stalled host shows up as wall time, nothing else:
                # push this tick past the straggler threshold so the
                # watch (and its re-shard callback story) exercises
                dt += self.cfg.straggler_factor * max(self._ewma or dt,
                                                      dt) + 1e-3

            # NaN guard: skip poisoned updates
            if not np.isfinite(loss):
                self.nan_skips += 1
                self.log(f"[ft] step {step}: non-finite loss, skipping "
                         f"update ({self.nan_skips}/{self.cfg.max_nan_skips})")
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise RuntimeError("too many non-finite steps")
            else:
                params, opt_state = new_params, new_opt

            # straggler watch
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.cfg.straggler_factor * self._ewma:
                self.straggler_events += 1
                self.log(f"[ft] step {step}: straggler ({dt:.3f}s vs "
                         f"EWMA {self._ewma:.3f}s)")
            self._ewma = (1 - self.cfg.ewma_alpha) * self._ewma \
                + self.cfg.ewma_alpha * dt

            metrics_hist.append({k: float(v) for k, v in metrics.items()})

            if (step + 1) % self.cfg.ckpt_every == 0 or self.preempted:
                self.ckpt.save(step + 1, (params, opt_state),
                               blocking=not self.cfg.async_ckpt)
            if self.preempted:
                self.ckpt.wait()
                self.log(f"[ft] clean exit at step {step + 1}")
                break
            step += 1
        self.ckpt.wait()
        return params, opt_state, metrics_hist
