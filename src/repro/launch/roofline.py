"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs / peak_FLOPs            (cost_analysis, per chip)
  memory     = HLO_bytes / HBM_bw                (cost_analysis, per chip)
  collective = wire_bytes / link_bw              (parsed from HLO text)

cost_analysis() of an SPMD-partitioned module reports per-device numbers;
collective wire bytes are parsed from ``compiled.as_text()`` (the
partitioned module, so shapes are per-device shards) with per-kind
ring-traffic factors.  Beyond the aggregate, ``parse_collectives`` emits
one ``CollectiveOp`` record per collective — HLO kind, semantic stream
(psum / head_all_gather / partial_combine / kv_migrate / ..., recovered
from the ``jax.named_scope`` labels ``repro.core.boundary`` puts on every
coded boundary), participant group size from the op's ``replica_groups``,
wire bytes, and whether the payload rides the coded (int8/int4) wire —
the per-collective packet streams the serving engine threads into the
cycle-level NoC co-simulation (``repro.sim.noc.NocSim.simulate_trace``).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

#: result dtypes that mark a coded-wire payload (spike counts / absmax
#: int8 / packed uint4); a collective whose every result leaf is one of
#: these moves boundary packets, not fp activations
_CODED_DTYPES = frozenset({"s8", "u8", "s4", "u4", "pred"})

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9\[\],{}<=]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|s4|u4)\[([0-9,]*)\]")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_NPART_RE = re.compile(r"num_partitions=(\d+)")

#: ``jax.named_scope`` labels (repro.core.boundary) -> semantic stream;
#: first substring match on the op's ``metadata.op_name`` wins
_STREAM_HINTS = (
    ("kv_migrate", "kv_migrate"),
    ("combine_partials", "partial_combine"),
    ("quantize_partial", "partial_combine"),
    ("head_all_gather", "head_all_gather"),
)
#: fallback: HLO op kind -> stream for collectives without a scope hint
_KIND_STREAMS = {
    "all-reduce": "psum",
    "reduce-scatter": "psum",
    "all-gather": "all_gather",
    "all-to-all": "all_to_all",
    "collective-permute": "permute",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> Optional[int]:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]<=[N]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return None


def _stream_of(op_name: str, kind: str) -> str:
    for hint, stream in _STREAM_HINTS:
        if hint in op_name:
            return stream
    return _KIND_STREAMS.get(kind, kind)


def _is_coded(type_str: str) -> bool:
    dts = [dt for dt, _ in _SHAPE_RE.findall(type_str)]
    return bool(dts) and all(dt in _CODED_DTYPES for dt in dts)


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One parsed collective: the unit of a per-collective packet stream."""

    kind: str                  # HLO op: all-gather | all-reduce | ...
    stream: str                # semantic stream (psum | head_all_gather |
    #                            partial_combine | kv_migrate | ...)
    group: int                 # participant count (replica_groups)
    t_bytes: float             # result tensor bytes (per device)
    bytes: float               # ring-model wire bytes (per device)
    coded: bool                # int8/int4 payload: the coded boundary
    op_name: str = ""          # HLO metadata op_name (scope trail)


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    wire_bytes: float          # per-device bytes on the ICI
    by_kind: dict
    ops: List[CollectiveOp] = dataclasses.field(default_factory=list)
    by_stream: dict = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str,
                      default_group: Optional[int] = None) -> CollectiveStats:
    """Sum per-device ICI traffic over every collective op.

    Ring-model factors (n = participant count, T = tensor bytes as printed
    on the op's *result*, which in the partitioned module is per-device):
      all-gather        result T (full):    recv (n-1)/n * T
      reduce-scatter    result T (shard):   recv (n-1) * T
      all-reduce        result T:           recv 2*(n-1)/n * T
      all-to-all        result T:           recv (n-1)/n * T
      collective-permute result T:          recv T

    ``n`` is parsed from each op's ``replica_groups`` (explicit or iota
    form); ops without one fall back to the module's ``num_partitions``
    header (the all-device group XLA prints as ``{}``), and only when
    neither is present does ``default_group`` apply — with a warning,
    because an assumed group size silently mis-scales wire bytes on any
    mesh whose HLO says otherwise (e.g. tp=4 all-gathers under the old
    hardwired ``default_group=2``).
    """
    counts: dict = {}
    by_kind: dict = {}
    by_stream: dict = {}
    ops: List[CollectiveOp] = []
    total = 0.0
    unsized = 0
    m = _NPART_RE.search(hlo_text)
    num_partitions = int(m.group(1)) if m else None
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2).lower()
        t_bytes = _shape_bytes(type_str)
        n = _group_size(line)
        if n is None:
            if kind == "collective-permute":
                n = 2          # point-to-point pairs; bytes are n-free
            else:
                n = num_partitions
            if n is None:
                unsized += 1
                n = default_group or 2
        if n <= 1:
            continue
        if kind == "all-gather":
            b = t_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            b = t_bytes * (n - 1)
        elif kind == "all-reduce":
            b = 2 * t_bytes * (n - 1) / n
        elif kind == "all-to-all":
            b = t_bytes * (n - 1) / n
        else:  # collective-permute
            b = t_bytes
        nm = _OPNAME_RE.search(line)
        op_name = nm.group(1) if nm else ""
        stream = _stream_of(op_name, kind)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0.0) + b
        by_stream[stream] = by_stream.get(stream, 0.0) + b
        ops.append(CollectiveOp(kind, stream, n, t_bytes, b,
                                _is_coded(type_str), op_name))
        total += b
    if unsized:
        warnings.warn(
            f"parse_collectives: {unsized} collective(s) carry no "
            f"replica_groups and the module prints no num_partitions; "
            f"assuming group size {default_group or 2} — wire bytes may "
            f"be mis-scaled", RuntimeWarning, stacklevel=2)
    return CollectiveStats(counts, total, by_kind, ops, by_stream)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    coll_counts: dict
    coll_by_kind: dict

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: dict, hlo_text: str, model_flops_per_chip: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    c_s = flops / PEAK_FLOPS
    m_s = hbm / HBM_BW
    i_s = coll.wire_bytes / ICI_BW
    terms = {"compute": c_s, "memory": m_s, "collective": i_s}
    bn = max(terms, key=terms.get)
    ratio = model_flops_per_chip / flops if flops else 0.0
    return Roofline(flops, hbm, coll.wire_bytes, c_s, m_s, i_s, bn,
                    model_flops_per_chip, ratio, coll.counts, coll.by_kind)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6ND dense / 6·N_active·D MoE)
# ---------------------------------------------------------------------------


def count_params(cfg, tp: int = 16):
    """(total, active) parameter counts from the config (analytic)."""
    D = cfg.d_model
    dh = cfg.d_head
    V = cfg.vocab
    total = V * D * (1 if cfg.tie_embeddings else 2)
    # 6ND convention: the embedding LOOKUP does no matmul flops; only the
    # LM-head matmul counts toward MODEL_FLOPS
    active = V * D
    per_kind_t = {}
    for kind in cfg.pattern:
        t = a = 0
        if kind in ("attn", "global", "local", "attn_moe"):
            t += D * cfg.n_heads * dh * 2            # wq, wo
            t += D * cfg.n_kv_heads * dh * 2         # wk, wv
            a = t
            if kind == "attn_moe":
                e = 3 * D * cfg.d_ff_expert
                t += cfg.n_experts * e + D * cfg.n_experts
                a += cfg.top_k * e
                sh = 3 * D * cfg.n_shared_experts * cfg.d_ff_expert
                t += sh
                a += sh
            else:
                t += 3 * D * cfg.d_ff
                a += 3 * D * cfg.d_ff
        elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
            Di = cfg.d_inner
            t += D * 2 * Di + Di * D + Di * cfg.d_conv
            t += 2 * D * cfg.d_state + D * cfg.dt_rank_eff \
                + cfg.dt_rank_eff * Di + 2 * Di * cfg.d_state
            a = t
            if kind == "mamba_moe":
                e = 3 * D * cfg.d_ff_expert
                t += cfg.n_experts * e
                a += cfg.top_k * e
            elif kind == "mamba_mlp":
                t += 3 * D * cfg.d_ff
                a += 3 * D * cfg.d_ff
        elif kind == "mlstm":
            t += 5 * D * cfg.n_heads * dh + 2 * D * cfg.n_heads
            a = t
        elif kind == "slstm":
            t += 5 * D * cfg.n_heads * dh \
                + cfg.n_heads * dh * 4 * dh
            a = t
        elif kind == "rwkv":
            F = cfg.d_ff or 4 * D
            t += 4 * D * D + D * F + F * D + D * D
            a = t
        per_kind_t[kind] = t
        total += t * cfg.n_units
        active += a * cfg.n_units
    if cfg.is_encdec:
        enc = (D * cfg.n_heads * dh * 2 + D * cfg.n_kv_heads * dh * 2
               + 3 * D * cfg.d_ff) * cfg.n_enc_layers
        cross = (D * cfg.n_heads * dh * 2 + D * cfg.n_kv_heads * dh * 2) \
            * cfg.n_layers
        total += enc + cross
        active += enc + cross
    return total, active


def model_flops_per_chip(cfg, cell, chips: int, mode: str) -> float:
    total, active = count_params(cfg, 16)
    tokens = cell.global_batch * cell.seq_len
    if mode == "train":
        return 6.0 * active * tokens / chips
    if mode == "prefill":
        return 2.0 * active * tokens / chips
    # decode: one token per sequence
    return 2.0 * active * cell.global_batch / chips
