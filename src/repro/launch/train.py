"""Distributed train step: FSDP(pod,data) x TP(model) + spike boundaries.

``make_train_step`` builds the jit'd shard_map step for an (arch, shape,
mesh) plan.  Gradients of FSDP-sharded weights reduce via the AD
transpose of the forward all_gather (ZeRO-2-style reduce-scatter);
replicated params get an explicit psum over the axes missing from their
spec.  Optimizer states are sharded exactly like the params (ZeRO-1).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import draft_heads as DH
from ..models import model as M
from ..models import params as PR
from ..optim import adamw
from .specs import CellPlan, make_context, train_input_specs

F32 = jnp.float32


def shard_params_specs(cfg, plan: CellPlan):
    defs = M.model_defs(cfg, plan.tp_size)
    pspecs = PR.specs_tree(defs, plan.dp, plan.tp)
    psum_axes = PR.grad_psum_axes(defs, plan.dp, plan.tp)
    return defs, pspecs, psum_axes


def pick_microbatches(cfg, plan: CellPlan) -> int:
    """Gradient-accumulation factor: keep per-micro activation footprint
    (tokens x d_model) bounded so one block's fwd+bwd fits HBM."""
    B_loc = max(1, plan.cell.global_batch // plan.dp_size)
    if plan.cell.kind != "train":
        return 1
    # target <= ~8k tokens/device/micro at d_model >= 4k, scaled up for
    # smaller models
    tokens = B_loc * plan.cell.seq_len
    target = 8192 * max(1, 4096 // max(cfg.d_model, 1024)) ** 1
    mb = max(1, tokens // max(target, 1))
    while B_loc % mb != 0:
        mb -= 1
    return max(1, min(mb, B_loc))


def make_train_step(cfg, plan: CellPlan, mesh, with_optimizer=True,
                    microbatches: int | None = None,
                    opt_cfg: adamw.AdamWConfig | None = None):
    """Returns (step_fn, params_specs, opt_specs, batch_specs).

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    (or (loss, grads) when with_optimizer=False).

    Gradient accumulation: the local batch is split into ``microbatches``
    slices scanned with an fp32 grad accumulator — the standard way a
    398B train step fits 16 GB HBM.
    """
    defs, pspecs, psum_axes = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "train")
    _, bspecs = train_input_specs(plan)
    n_micro = microbatches or pick_microbatches(cfg, plan)

    def loss_fn(params, batch):
        return M.forward_loss(params, batch, ctx)

    def micro_grads(params, batch):
        """Accumulate grads over microbatches (fp32)."""
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, grads, metrics

        def split(x):
            b, rest = x.shape[0], x.shape[1:]
            return x.reshape(n_micro, b // n_micro, *rest)

        def split_batch(b):
            out = {}
            for k, v in b.items():
                if k == "positions3":
                    out[k] = jnp.moveaxis(
                        v.reshape(3, n_micro, v.shape[1] // n_micro,
                                  *v.shape[2:]), 1, 0)
                else:
                    out[k] = split(v)
            return out

        mb = split_batch(batch)

        def body(acc, mslice):
            gacc, lacc, macc = acc
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mslice)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            macc = jax.tree.map(lambda a, m: a + m, macc, metrics)
            return (gacc, lacc + loss, macc), None

        gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mz = {"loss": jnp.zeros((), F32), "penalty": jnp.zeros((), F32),
              "occupancy": jnp.zeros((), F32)}
        (gacc, loss, macc), _ = jax.lax.scan(
            body, (gz, jnp.zeros((), F32), mz), mb)
        inv = 1.0 / n_micro
        grads = jax.tree.map(lambda g: g * inv, gacc)
        metrics = jax.tree.map(lambda m: m * inv, macc)
        return loss * inv, grads, metrics

    def grads_psum(grads):
        def fix(g, axes):
            for a in axes:
                g = jax.lax.psum(g, a)
            return g
        return jax.tree.map(fix, grads, psum_axes)

    if not with_optimizer:
        def step(params, batch):
            loss, grads, metrics = micro_grads(params, batch)
            grads = grads_psum(grads)
            metrics = {k: jax.lax.pmean(v, plan.dp + (plan.tp,))
                       for k, v in metrics.items()}
            return loss, grads, metrics

        fn = jax.shard_map(step, mesh=mesh,
                           in_specs=(pspecs, bspecs),
                           out_specs=(P(), pspecs, {k: P() for k in
                                                    ("loss", "penalty",
                                                     "occupancy")}),
                           check_vma=False)
        return jax.jit(fn), pspecs, None, bspecs

    opt_specs = adamw.opt_state_specs(pspecs)
    all_axes = plan.dp + (plan.tp,)

    def global_grad_norm(grads):
        """Exact global norm: sharded leaves psum over their sharding
        axes (disjoint shards); replicated leaves contribute once."""
        buckets: dict[tuple, Any] = {}
        for g, rep_axes in zip(jax.tree.leaves(grads),
                               jax.tree.leaves(
                                   psum_axes,
                                   is_leaf=lambda x: isinstance(x, tuple))):
            shard_axes = tuple(a for a in all_axes if a not in rep_axes)
            s = jnp.sum(jnp.square(g.astype(F32)))
            buckets[shard_axes] = buckets.get(shard_axes, 0.0) + s
        total = 0.0
        for axes, s in buckets.items():
            total = total + (jax.lax.psum(s, axes) if axes else s)
        return jnp.sqrt(total)

    def step(params, opt_state, batch):
        loss, grads, metrics = micro_grads(params, batch)
        grads = grads_psum(grads)
        gnorm = global_grad_norm(grads)
        params, opt_state = adamw.apply_updates(
            params, grads, opt_state, gnorm=gnorm,
            cfg=opt_cfg or adamw.AdamWConfig())
        metrics = {k: jax.lax.pmean(v, plan.dp + (plan.tp,))
                   for k, v in metrics.items()}
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    mspec = {k: P() for k in ("loss", "penalty", "occupancy", "grad_norm")}
    fn = jax.shard_map(step, mesh=mesh,
                       in_specs=(pspecs, opt_specs, bspecs),
                       out_specs=(pspecs, opt_specs, mspec),
                       check_vma=False)
    return jax.jit(fn, donate_argnums=(0, 1)), pspecs, opt_specs, bspecs


def make_draft_head_train_step(cfg, plan: CellPlan, mesh, num_heads: int,
                               d_hidden: int = 0,
                               opt_cfg: adamw.AdamWConfig | None = None):
    """Frozen-trunk, heads-only train step (the draft-head mode).

    Returns (step_fn, params_specs, opt_specs, batch_specs) with
    step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    ``params`` is ONE tree: the trunk plus a ``"draft_heads"`` subtree
    (see ``models.draft_heads.draft_head_defs``).  Only the heads
    subtree differentiates — the trunk forward runs under stop_gradient
    inside ``draft_head_loss`` — and ``opt_state`` covers the heads
    alone, so the optimizer footprint is O(heads).  The full tree flows
    through unchanged otherwise, which is what lets the caller's
    checkpoint loop (runtime.ft.TrainLoop) save trunk + heads together
    as one path-keyed manifest.
    """
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    hdefs = DH.draft_head_defs(cfg, num_heads, d_hidden)
    hspecs = PR.specs_tree(hdefs, plan.dp, plan.tp)
    hpsum = PR.grad_psum_axes(hdefs, plan.dp, plan.tp)
    pspecs_full = dict(pspecs)
    pspecs_full["draft_heads"] = hspecs
    ctx = make_context(plan, "train")
    _, bspecs = train_input_specs(plan)
    opt_specs = adamw.opt_state_specs(hspecs)

    def hloss(hp, params, batch):
        p = dict(params)
        p["draft_heads"] = hp
        return DH.draft_head_loss(p, batch, ctx)

    def step(params, opt_state, batch):
        hp = params["draft_heads"]
        (loss, metrics), grads = jax.value_and_grad(
            hloss, has_aux=True)(hp, params, batch)

        def fix(g, axes):
            for a in axes:
                g = jax.lax.psum(g, a)
            return g

        grads = jax.tree.map(fix, grads, hpsum)
        # heads are replicated and their grads are post-psum identical on
        # every rank: the global norm is a plain local sum of squares
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                             for g in jax.tree.leaves(grads)))
        hp, opt_state = adamw.apply_updates(
            hp, grads, opt_state, gnorm=gnorm,
            cfg=opt_cfg or adamw.AdamWConfig())
        params = dict(params)
        params["draft_heads"] = hp
        metrics = {k: jax.lax.pmean(v, plan.dp + (plan.tp,))
                   for k, v in metrics.items()}
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    mspec = {k: P() for k in ("loss", "draft_acc", "grad_norm")}
    fn = jax.shard_map(step, mesh=mesh,
                       in_specs=(pspecs_full, opt_specs, bspecs),
                       out_specs=(pspecs_full, opt_specs, mspec),
                       check_vma=False)
    return jax.jit(fn, donate_argnums=(0, 1)), pspecs_full, opt_specs, bspecs


def init_draft_head_params(cfg, plan: CellPlan, mesh, key, num_heads: int,
                           d_hidden: int = 0, dtype=None):
    """Materialize a fresh (identity-init) draft-heads subtree, sharded
    (i.e. replicated — the defs carry no tp/fsdp dims) on the mesh."""
    hdefs = DH.draft_head_defs(cfg, num_heads, d_hidden)
    hspecs = PR.specs_tree(hdefs, plan.dp, plan.tp)
    host = PR.init_params(hdefs, key, dtype or cfg.dtype)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), hspecs)
    return jax.device_put(host, shardings)


def init_sharded_params(cfg, plan: CellPlan, mesh, key, dtype=None):
    """Materialize params sharded on the mesh (for real runs, not dryrun)."""
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    dtype = dtype or cfg.dtype
    host = PR.init_params(defs, key, dtype)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    return jax.device_put(host, shardings)


def abstract_sharded_params(cfg, plan: CellPlan):
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    return PR.abstract_params(defs, plan.cfg.dtype), pspecs
