"""Serving steps: prefill (fills context-parallel caches) and decode.

decode_step lowers the ``serve_step`` required by the decode_* / long_*
cells: one new token against a KV/state cache of cell.seq_len, with the
cache seq-sharded over the context-parallel axes (ctx.cp).

These are the single-request building blocks.  The batched
continuous-batching engine (slot scheduling, paged cache, fused
distributed sampling) lives in ``repro.serving``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import model as M
from .specs import (CellPlan, _bspec, cache_specs, decode_input_specs,
                    make_context, train_input_specs)
from .train import shard_params_specs


def strip_dp_specs(pspecs):
    """Drop the data axes from a param spec tree (weights replicated over
    dp, tp-sharded only — the production inference layout)."""
    def strip(spec):
        ents = tuple(None if (e is not None and e != "model") else e
                     for e in spec)
        return P(*ents)
    return jax.tree.map(strip, pspecs, is_leaf=lambda x: isinstance(x, P))


def make_prefill_step(cfg, plan: CellPlan, mesh):
    """prefill(params, batch) -> (last_logits_local, cache)."""
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "prefill")
    _, bspecs = train_input_specs(plan)
    _, cspecs = cache_specs(plan)
    bs = _bspec(plan)

    def step(params, batch):
        logits, caches = M.forward_prefill(params, batch, ctx)
        return logits, caches

    fn = jax.shard_map(step, mesh=mesh,
                       in_specs=(pspecs, bspecs),
                       out_specs=(P(bs, "model"), cspecs),
                       check_vma=False)
    return jax.jit(fn), pspecs, bspecs, cspecs


def make_decode_step(cfg, plan: CellPlan, mesh, replicate_weights=False):
    """decode(params, cache, token, pos) -> (logits_local, new_cache).

    ``replicate_weights=True`` stores params replicated over the data
    axes (tp-sharded only) — the production inference layout: no per-step
    FSDP weight gathers on the decode path (§Perf hillclimb, cell C).
    """
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        pspecs = strip_dp_specs(pspecs)
        ctx = ctx.with_(dp_size=1)   # fsdp_gather becomes a no-op
    _, ispecs = decode_input_specs(plan)
    bs = ispecs["token"]

    def step(params, cache, token, pos):
        return M.forward_decode(params, cache, token, pos, ctx)

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"]),
        out_specs=(P(*(tuple(bs) + ("model",))), ispecs["cache"]),
        check_vma=False)
    return jax.jit(fn, donate_argnums=(1,)), pspecs, ispecs


def make_logits_step(cfg, plan: CellPlan, mesh):
    """Full-sequence teacher-forced logits (parity / eval harness).

    logits(params, batch) -> [B, S, V] float32 — the same boundary codec
    path as training, no loss reduction.  Used to cross-check that N
    steps of engine decode reproduce the teacher-forced argmax.
    """
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "train").with_(collect_stats=False)
    _, bspecs = train_input_specs(plan)
    bs = _bspec(plan)

    def step(params, batch):
        aux = M._make_aux(batch, ctx)
        x = M.embed_tokens(params, batch["tokens"], ctx)
        x, _, _, _ = M._run_stack(params, x, ctx, aux)
        logits, _ = M.lm_logits_local(params, x, ctx)
        return logits

    fn = jax.shard_map(step, mesh=mesh, in_specs=(pspecs, bspecs),
                       out_specs=P(bs, None, "model"), check_vma=False)
    return jax.jit(fn)


def greedy_sample(logits_local, mesh, plan: CellPlan):
    """Greedy next-token from gathered logits [B, V] (host-side).

    Example-driver helper only; the serving engine samples on-device
    from tp-sharded logits (``repro.serving.sampling``).
    """
    return jnp.argmax(logits_local, axis=-1).astype(jnp.int32)
