"""Serving steps: prefill (fills context-parallel caches) and decode.

decode_step lowers the ``serve_step`` required by the decode_* / long_*
cells: one new token against a KV/state cache of cell.seq_len, with the
cache seq-sharded over the context-parallel axes (ctx.cp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import model as M
from .specs import (CellPlan, cache_specs, decode_input_specs, make_context,
                    train_input_specs)
from .train import shard_params_specs


def make_prefill_step(cfg, plan: CellPlan, mesh):
    """prefill(params, batch) -> (last_logits_local, cache)."""
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "prefill")
    _, bspecs = train_input_specs(plan)
    _, cspecs = cache_specs(plan)
    bs = None if not plan.batch_sharded else (
        plan.dp if len(plan.dp) > 1 else plan.dp[0])

    def step(params, batch):
        logits, caches = M.forward_prefill(params, batch, ctx)
        return logits, caches

    fn = jax.shard_map(step, mesh=mesh,
                       in_specs=(pspecs, bspecs),
                       out_specs=(P(bs, "model"), cspecs),
                       check_vma=False)
    return jax.jit(fn), pspecs, bspecs, cspecs


def make_decode_step(cfg, plan: CellPlan, mesh, replicate_weights=False):
    """decode(params, cache, token, pos) -> (logits_local, new_cache).

    ``replicate_weights=True`` stores params replicated over the data
    axes (tp-sharded only) — the production inference layout: no per-step
    FSDP weight gathers on the decode path (§Perf hillclimb, cell C).
    """
    defs, pspecs, _ = shard_params_specs(cfg, plan)
    ctx = make_context(plan, "decode")
    if replicate_weights:
        import jax as _jax
        from jax.sharding import PartitionSpec as _P

        def strip_dp(spec):
            ents = tuple(None if (e is not None and e != "model") else e
                         for e in spec)
            return _P(*ents)
        pspecs = _jax.tree.map(strip_dp, pspecs,
                               is_leaf=lambda x: isinstance(x, _P))
        ctx = ctx.with_(dp_size=1)   # fsdp_gather becomes a no-op
    _, ispecs = decode_input_specs(plan)
    bs = ispecs["token"]

    def step(params, cache, token, pos):
        return M.forward_decode(params, cache, token, pos, ctx)

    fn = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ispecs["cache"], ispecs["token"], ispecs["pos"]),
        out_specs=(P(*(tuple(bs) + ("model",))), ispecs["cache"]),
        check_vma=False)
    return jax.jit(fn, donate_argnums=(1,)), pspecs, ispecs


def greedy_sample(logits_local, mesh, plan: CellPlan):
    """Greedy next-token from tp-sharded logits [B, V_loc] (host-side)."""
    # logits gathered by jit output sharding; argmax on host is fine for
    # the example drivers
    return jnp.argmax(logits_local, axis=-1).astype(jnp.int32)
