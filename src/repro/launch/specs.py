"""Input/cache ShapeDtypeStructs + PartitionSpecs per (arch x shape cell).

This is the dry-run contract: everything jit'd in train.py/serve.py is
lowered against these stand-ins (weak-type-correct, shardable, no device
allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCell
from ..models import blocks_attn, blocks_rnn, blocks_ssm
from ..models.context import Context, codec_from_name

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """Static plan for one (arch, shape, mesh) cell."""

    cfg: ModelConfig
    cell: ShapeCell
    dp: tuple                    # data axes
    tp: str
    dp_size: int
    tp_size: int
    batch_sharded: bool          # batch over dp? (False -> replicated)
    cp: tuple                    # context-parallel axes for decode


def make_plan(cfg: ModelConfig, cell: ShapeCell, mesh) -> CellPlan:
    names = mesh.axis_names
    dp = tuple(n for n in names if n != "model")
    tp = "model"
    dp_size = 1
    for n in dp:
        dp_size *= mesh.shape[n]
    tp_size = mesh.shape[tp]
    batch_sharded = cell.global_batch % dp_size == 0
    if cell.kind == "decode":
        cp = (tp,) if batch_sharded else dp + (tp,)
    else:
        cp = (tp,)
    return CellPlan(cfg, cell, dp, tp, dp_size, tp_size, batch_sharded, cp)


def make_context(plan: CellPlan, mode: str) -> Context:
    cfg = plan.cfg
    codec = codec_from_name(cfg.codec, cfg.hnn_mode)
    return Context(cfg=cfg, dp=plan.dp, tp=plan.tp, dp_size=plan.dp_size,
                   tp_size=plan.tp_size, codec=codec, mode=mode, cp=plan.cp)


def _bspec(plan: CellPlan):
    """PartitionSpec entry for the global batch dim."""
    if not plan.batch_sharded:
        return None
    return plan.dp if len(plan.dp) > 1 else plan.dp[0]


# ---------------------------------------------------------------------------
# train / prefill inputs
# ---------------------------------------------------------------------------


def train_input_specs(plan: CellPlan):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for a train batch."""
    cfg, cell = plan.cfg, plan.cell
    B, S = cell.global_batch, cell.seq_len
    bs = _bspec(plan)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs = {"tokens": P(bs, plan.tp), "labels": P(bs, plan.tp)}
    batch = {"tokens": tok, "labels": tok}
    if cfg.is_encdec:
        # half the token budget to the encoder (frame embeddings), half
        # to the decoder (text): S_enc = S_dec = S/2
        S2 = S // 2
        batch = {"tokens": jax.ShapeDtypeStruct((B, S2), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S2), jnp.int32),
                 "enc_embeds": jax.ShapeDtypeStruct((B, S2, cfg.d_model),
                                                    cfg.dtype)}
        specs = {"tokens": P(bs, plan.tp), "labels": P(bs, plan.tp),
                 "enc_embeds": P(bs, plan.tp, None)}
    if cfg.rope_kind == "mrope":
        batch["positions3"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        specs["positions3"] = P(None, bs, plan.tp)
    return batch, specs


# ---------------------------------------------------------------------------
# decode inputs (KV/state caches)
# ---------------------------------------------------------------------------


def cache_specs(plan: CellPlan):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the decode cache."""
    cfg, cell = plan.cfg, plan.cell
    tp = plan.tp_size
    U = cfg.n_units
    B, S = cell.global_batch, cell.seq_len
    bs = _bspec(plan)
    cps = plan.cp if len(plan.cp) > 1 else plan.cp[0]
    dt = cfg.dtype

    structs: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    d_at = blocks_attn.attn_dims(cfg, tp)

    for i, kind in enumerate(cfg.pattern):
        st: dict[str, Any] = {}
        sp: dict[str, Any] = {}
        if kind in ("attn", "global", "local", "attn_moe"):
            shape = (U, B, S, d_at["Hkv"], d_at["dh"])
            st["kv"] = {"k": jax.ShapeDtypeStruct(shape, dt),
                        "v": jax.ShapeDtypeStruct(shape, dt)}
            sp["kv"] = {"k": P(None, bs, cps, None, None),
                        "v": P(None, bs, cps, None, None)}
            if cfg.is_encdec:
                S_enc = max(cell.seq_len // 8, 32)
                xshape = (U, B, S_enc, d_at["Hkv"], d_at["dh"])
                st["cross_kv"] = {"k": jax.ShapeDtypeStruct(xshape, dt),
                                  "v": jax.ShapeDtypeStruct(xshape, dt)}
                sp["cross_kv"] = sp["kv"]
        elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
            d = blocks_ssm.ssm_dims(cfg, tp)
            st["ssm_state"] = {
                "conv": jax.ShapeDtypeStruct((U, B, d["K"] - 1, d["Di"]), dt),
                "ssm": jax.ShapeDtypeStruct((U, B, d["Di"], d["N"]), F32)}
            sp["ssm_state"] = {"conv": P(None, bs, None, plan.tp),
                               "ssm": P(None, bs, plan.tp, None)}
        elif kind == "mlstm":
            d = blocks_rnn.mlstm_dims(cfg, tp)
            st["rnn_state"] = {
                "C": jax.ShapeDtypeStruct((U, B, d["H"], d["dh"], d["dh"]),
                                          F32),
                "n": jax.ShapeDtypeStruct((U, B, d["H"], d["dh"]), F32),
                "m": jax.ShapeDtypeStruct((U, B, d["H"]), F32)}
            sp["rnn_state"] = {"C": P(None, bs, plan.tp, None, None),
                               "n": P(None, bs, plan.tp, None),
                               "m": P(None, bs, plan.tp)}
        elif kind == "slstm":
            d = blocks_rnn.mlstm_dims(cfg, tp)
            shape = (U, B, d["H"], d["dh"])
            st["rnn_state"] = {k: jax.ShapeDtypeStruct(shape, F32)
                               for k in ("c", "n", "h", "m")}
            sp["rnn_state"] = {k: P(None, bs, plan.tp, None)
                               for k in ("c", "n", "h", "m")}
        elif kind == "rwkv":
            d = blocks_rnn.rwkv_dims(cfg, tp)
            D = cfg.d_model
            st["rwkv_state"] = {
                "x_tm": jax.ShapeDtypeStruct((U, B, D), dt),
                "x_cm": jax.ShapeDtypeStruct((U, B, D), dt),
                "aa": jax.ShapeDtypeStruct((U, B, d["C"]), F32),
                "bb": jax.ShapeDtypeStruct((U, B, d["C"]), F32),
                "pp": jax.ShapeDtypeStruct((U, B, d["C"]), F32)}
            sp["rwkv_state"] = {
                "x_tm": P(None, bs, None), "x_cm": P(None, bs, None),
                "aa": P(None, bs, plan.tp), "bb": P(None, bs, plan.tp),
                "pp": P(None, bs, plan.tp)}
        structs[f"pos{i}"] = st
        specs[f"pos{i}"] = sp
    return structs, specs


def pages_per_slot(max_seq: int, page_size: int) -> int:
    """Block-table width: pages a slot at full ``max_seq`` occupancy maps."""
    return -(-max_seq // page_size)


def default_num_pages(plan: CellPlan, page_size: int) -> int:
    """Pool size that reproduces the old dense reservation exactly.

    Per dp group: every local slot can map ``pages_per_slot`` pages
    (rounded up to a tp multiple so the pool dim shards evenly over the
    dp x tp devices).  With this default the pool can never exhaust
    before the slot count does — byte-for-byte the old guarantee — and
    shrinking ``num_pages`` below it is the knob paging buys.
    """
    pps = pages_per_slot(plan.cell.seq_len, page_size)
    slots_loc = plan.cell.global_batch // plan.dp_size
    per_group = -(-slots_loc * pps // plan.tp_size) * plan.tp_size
    return per_group * plan.dp_size


def _pool_axes(plan: CellPlan):
    """Mesh axes the page-pool dim shards over: ALL of them (dp x tp).

    Slots are batch-sharded over dp and each slot's pages are drawn from
    its own dp group's contiguous page range (allocator invariant), so
    sharding pages over dp+tp keeps every slot's pages on its own dp
    group's tp shards — the flash-decode LSE combine stays over
    ``plan.cp`` exactly as in the dense layout.
    """
    return tuple(plan.dp) + (plan.tp,)


def paged_cache_specs(plan: CellPlan, page_size: int, num_pages: int):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the POOLED cache.

    Attention KV leaves become a shared device page pool
    ``[U, num_pages, page_size, Hkv, dh]`` with the page dim sharded
    over dp x tp (see ``_pool_axes``); recurrent/SSM state leaves stay
    slot-major — only attention KV pages (state cannot be paged: it is
    O(1) per slot and every block reads all of it every step).
    """
    cfg = plan.cfg
    if cfg.is_encdec:
        raise NotImplementedError(
            "paged KV for encoder-decoder (cross_kv) serving: follow-on")
    structs, specs = cache_specs(plan)
    d_at = blocks_attn.attn_dims(cfg, plan.tp_size)
    shape = (cfg.n_units, num_pages, page_size, d_at["Hkv"], d_at["dh"])
    sp = P(None, _pool_axes(plan), None, None, None)
    for i, kind in enumerate(cfg.pattern):
        if kind in ("attn", "global", "local", "attn_moe"):
            structs[f"pos{i}"]["kv"] = {
                "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.dtype)}
            specs[f"pos{i}"]["kv"] = {"k": sp, "v": sp}
    return structs, specs


def block_table_specs(plan: CellPlan, page_size: int):
    """(ShapeDtypeStruct, PartitionSpec) of the per-slot block table.

    ``[slots, pages_per_slot]`` int32 global page ids (-1 = unmapped),
    slot dim batch-sharded like the tokens so each dp rank sees exactly
    its local slots' rows; replicated over tp (every tp shard needs the
    full row to find its resident pages).
    """
    B, S = plan.cell.global_batch, plan.cell.seq_len
    pps = pages_per_slot(S, page_size)
    return (jax.ShapeDtypeStruct((B, pps), jnp.int32),
            P(_bspec(plan), None))


def page_list_specs(plan: CellPlan, page_size: int):
    """(ShapeDtypeStructs, PartitionSpecs) of the compacted page lists.

    Two ``[slots, pool_shards, pages_per_shard]`` int32 arrays (local
    page row / absolute start position, -1 = no page) built by the
    allocator alongside the block table and staged per dispatch the same
    way.  The slot dim is batch-sharded like the tokens; the shard dim
    is sharded over tp so each device receives exactly ITS OWN
    ``[B_loc, 1, pages_per_shard]`` list — the fused paged-decode kernel
    walks only these entries instead of the full ``pages_per_slot``-wide
    table.  ``pages_per_shard = ceil(pages_per_slot / pool_shards_per_
    group)``: the 1/cp page-count reduction the dense layout had.
    """
    B, S = plan.cell.global_batch, plan.cell.seq_len
    groups = plan.dp_size if plan.batch_sharded else 1
    shards = (plan.dp_size * plan.tp_size) // groups
    pps = -(-pages_per_slot(S, page_size) // shards)
    struct = jax.ShapeDtypeStruct((B, shards, pps), jnp.int32)
    # shard dim over tp only when slots are dp-sharded (shards == tp);
    # in the replicated-batch case the shard dim spans dp x tp
    saxes = plan.tp if plan.batch_sharded else _pool_axes(plan)
    sp = P(_bspec(plan), saxes, None)
    return (struct, struct), (sp, sp)


def migrate_input_specs(plan: CellPlan, page_size: int):
    """(inputs, specs) for the KV migration step's host-staged feeds.

    The disaggregated engine's migration program takes, besides the
    donated pool cache, four replicated host feeds: the SOURCE slot's
    block-table row snapshot and the freshly mirrored DESTINATION row
    (``[pages_per_slot]`` int32 global page ids, -1 unmapped) plus the
    two slot indices (scalar int32).  Replicated (``P()``) on purpose:
    every device must see both rows — each tp shard resolves its own
    resident pages through ``pool_local_pages`` exactly as the insert
    path does, and the dp groups at either end of the ppermute need the
    row of their side of the handoff.
    """
    pps = pages_per_slot(plan.cell.seq_len, page_size)
    inputs = {"src_bt": jax.ShapeDtypeStruct((pps,), jnp.int32),
              "dst_bt": jax.ShapeDtypeStruct((pps,), jnp.int32),
              "src_slot": jax.ShapeDtypeStruct((), jnp.int32),
              "dst_slot": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = {"src_bt": P(), "dst_bt": P(), "src_slot": P(),
             "dst_slot": P()}
    return inputs, specs


def migrate_stage_shape(plan: CellPlan, page_size: int,
                        kv_leaf_shape) -> tuple:
    """Shape of ONE per-shard KV migration staging buffer.

    The device migration gathers the source slot's resident pages on
    each tp shard into a static ``[U, pages_per_slot, page_size, Hkv,
    dh]`` slab (non-resident rows zero), ppermutes the slab to the
    destination group's same-index shard, and scatters it through the
    mirrored destination block row.  Static width = the full block-row
    span: the wire cost of a migration is therefore shape-constant per
    (src, dst) pair — which is what lets the host price it without
    reading device state (``boundary.kv_wire_bytes``).
    """
    U, _, psz, Hkv, dh = kv_leaf_shape
    return (U, pages_per_slot(plan.cell.seq_len, page_size), psz, Hkv, dh)


def decode_input_specs(plan: CellPlan):
    """(inputs, specs) for one decode step: cache + token + pos."""
    cfg, cell = plan.cfg, plan.cell
    B = cell.global_batch
    bs = _bspec(plan)
    cache, cache_sp = cache_specs(plan)
    inputs = {"cache": cache,
              "token": jax.ShapeDtypeStruct((B,), jnp.int32),
              "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = {"cache": cache_sp, "token": P(bs), "pos": P()}
    return inputs, specs


def serve_decode_input_specs(plan: CellPlan, page_size: int,
                             num_pages: int):
    """(inputs, specs) for one batched engine decode step.

    Differs from ``decode_input_specs`` in the scheduler-facing inputs
    (per-slot positions and sampling temperatures, batch-sharded like
    the tokens, plus a replicated PRNG key) and in the cache layout:
    the engine cache is the shared KV page pool + per-slot block table
    (``paged_cache_specs`` / ``block_table_specs``).
    """
    cfg, cell = plan.cfg, plan.cell
    B = cell.global_batch
    bs = _bspec(plan)
    cache, cache_sp = paged_cache_specs(plan, page_size, num_pages)
    bt, bt_sp = block_table_specs(plan, page_size)
    (clp, clo), (clp_sp, clo_sp) = page_list_specs(plan, page_size)
    inputs = {"cache": cache,
              "token": jax.ShapeDtypeStruct((B,), jnp.int32),
              "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
              "bt": bt, "clp": clp, "clo": clo,
              "temp": jax.ShapeDtypeStruct((B,), jnp.float32),
              "key": jax.ShapeDtypeStruct((2,), jnp.uint32)}
    specs = {"cache": cache_sp, "token": P(bs), "pos": P(bs),
             "bt": bt_sp, "clp": clp_sp, "clo": clo_sp,
             "temp": P(bs), "key": P()}
    return inputs, specs


def serve_feed_specs(plan: CellPlan, page_size: int, spec_k: int = 0):
    """PartitionSpecs for the engine's per-dispatch feed staging.

    The async engine (``EngineConfig.async_depth > 0``) double-buffers
    its scheduler-facing inputs: each dispatch stages a FRESH device
    copy of the host token/pos/temp arrays and block table (via
    ``jax.device_put`` with these specs), while the in-flight step keeps
    sole ownership of the previous copies — host-side scheduling can
    then mutate its arrays for step t+1 without racing step t's
    transfer.  Staging with the step's own input sharding also means no
    reshard sits between the feed and the compiled shard_map program.
    ``vtoken`` (present when ``spec_k > 0``) is the [B, spec_k+1]
    speculative token block of a verify step.
    """
    bs = _bspec(plan)
    _, bt_sp = block_table_specs(plan, page_size)
    _, (clp_sp, clo_sp) = page_list_specs(plan, page_size)
    specs = {"token": P(bs), "pos": P(bs), "temp": P(bs), "bt": bt_sp,
             "clp": clp_sp, "clo": clo_sp}
    if spec_k > 0:
        specs["vtoken"] = P(bs, None)
    return specs


def verify_shape_cell(max_seq: int, num_slots: int, spec_k: int) -> ShapeCell:
    """Shape cell for the speculative k-token verify program.

    Same (seq_len, batch, kind) footprint as the decode cell — the verify
    step reads/writes the same slot-major cache — but named per ``spec_k``
    so dry-run/roofline tables key the two compiled programs apart.
    """
    return ShapeCell(f"serve_verify_k{spec_k}", max_seq, num_slots, "decode")


def serve_verify_input_specs(plan: CellPlan, spec_k: int, page_size: int,
                             num_pages: int):
    """(inputs, specs) for one batched speculative-verify step.

    Like ``serve_decode_input_specs`` but with K1 = spec_k+1 token
    columns per slot (last committed token + spec_k draft tokens) and a
    per-slot *base* position; the sampled-output token block is [B, K1].
    The cache is the same page pool + block table as the decode step —
    the two programs alternate over one donated buffer set.
    """
    cfg, cell = plan.cfg, plan.cell
    B = cell.global_batch
    bs = _bspec(plan)
    cache, cache_sp = paged_cache_specs(plan, page_size, num_pages)
    bt, bt_sp = block_table_specs(plan, page_size)
    (clp, clo), (clp_sp, clo_sp) = page_list_specs(plan, page_size)
    K1 = spec_k + 1
    inputs = {"cache": cache,
              "token": jax.ShapeDtypeStruct((B, K1), jnp.int32),
              "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
              "bt": bt, "clp": clp, "clo": clo,
              "temp": jax.ShapeDtypeStruct((B,), jnp.float32),
              "key": jax.ShapeDtypeStruct((2,), jnp.uint32)}
    specs = {"cache": cache_sp, "token": P(bs, None), "pos": P(bs),
             "bt": bt_sp, "clp": clp_sp, "clo": clo_sp,
             "temp": P(bs), "key": P()}
    return inputs, specs


def serve_heads_feed_specs(plan: CellPlan, page_size: int, spec_k: int):
    """PartitionSpecs for the HEADS-drafter verify feed chain.

    With ``EngineConfig.drafter = "heads"`` the verify step itself emits
    the next dispatch's inputs — ``vtoken`` [B, spec_k+1] (corrected
    token + head-argmax drafts) and ``vpos`` [B] (base position advanced
    by the accepted length) — which the engine chains device-to-device
    exactly like the async decode token feed (PR 5): no host join sits
    between verify dispatches.  ``vpos`` shares the ``pos`` layout; it
    gets its own key because the heads chain stages BOTH arrays fresh
    only on re-seed (admission / post-suspend), not per dispatch.
    """
    specs = serve_feed_specs(plan, page_size, spec_k)
    specs["vpos"] = specs["pos"]
    return specs
