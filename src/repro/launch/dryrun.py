import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements — jax locks the
device count on first init, and the production meshes need 512 host
placeholder devices (16x16 single-pod, 2x16x16 multi-pod).

Per cell this script:
  1. builds the production mesh and the cell plan,
  2. lowers the train_step / prefill_step / serve_step against
     ShapeDtypeStruct stand-ins (no allocation),
  3. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis() (FLOPs/bytes for the roofline),
  4. parses collective wire bytes from the partitioned HLO and emits the
     three-term roofline (EXPERIMENTS.md SS Dry-run / SS Roofline).

Results are appended to a JSON cache so the 80-cell sweep is resumable
(fault tolerance for the dry-run itself).

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, codec: str,
             hnn_mode: str, out_path: str | None):
    import jax
    import jax.numpy as jnp

    from ..configs import ASSIGNED, SHAPES, get_config
    from ..models import params as PR
    from ..optim import adamw
    from . import roofline as RL
    from . import serve as SV
    from . import specs as SP
    from . import train as TR
    from .mesh import make_production_mesh

    t0 = time.time()
    cfg = get_config(arch, codec=codec, hnn_mode=hnn_mode)
    cell = SHAPES[shape]

    # applicability gates (DESIGN.md SS5)
    if shape == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape,
                "multi_pod": multi_pod, "codec": codec,
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention; "
                          "full-attention arch (DESIGN.md SS5)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for n in mesh.axis_names:
        chips *= mesh.shape[n]
    plan = SP.make_plan(cfg, cell, mesh)

    mode = cell.kind
    if mode == "train":
        step, pspecs, opt_specs, bspecs = TR.make_train_step(
            cfg, plan, mesh, with_optimizer=True)
        aparams, _ = TR.abstract_sharded_params(cfg, plan)
        aopt = adamw.abstract_opt_state(aparams)
        abatch, _ = SP.train_input_specs(plan)
        lowered = step.lower(aparams, aopt, abatch)
    elif mode == "prefill":
        step, pspecs, bspecs, cspecs = SV.make_prefill_step(cfg, plan, mesh)
        aparams, _ = TR.abstract_sharded_params(cfg, plan)
        abatch, _ = SP.train_input_specs(plan)
        lowered = step.lower(aparams, abatch)
    else:  # decode
        step, pspecs, ispecs = SV.make_decode_step(
            cfg, plan, mesh,
            replicate_weights=os.environ.get("REPRO_SERVE_REPLICATED",
                                             "0") == "1")
        aparams, _ = TR.abstract_sharded_params(cfg, plan)
        ainputs, _ = SP.decode_input_specs(plan)
        lowered = step.lower(aparams, ainputs["cache"], ainputs["token"],
                             ainputs["pos"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k))
           for k in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes")
           if hasattr(ma, k)}
    print(f"memory_analysis[{arch}/{shape}]:", mem)
    cost = compiled.cost_analysis()
    print(f"cost_analysis[{arch}/{shape}]: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")

    hlo = compiled.as_text()
    mf = RL.model_flops_per_chip(cfg, cell, chips, mode)
    rf = RL.analyze(cost, hlo, mf)

    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "codec": codec, "hnn_mode": hnn_mode, "mode": mode,
        "chips": chips, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "roofline": rf.to_dict(),
    }
    return rec


def append_result(rec, out_path):
    with open(out_path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def already_done(out_path, key):
    try:
        with open(out_path) as f:
            for line in f:
                r = json.loads(line)
                if (r["arch"], r["shape"], r["multi_pod"],
                        r.get("codec")) == key:
                    return True
    except FileNotFoundError:
        pass
    return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--codec", default=None,
                    help="boundary codec override (default: config's)")
    ap.add_argument("--hnn-mode", default="hnn")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--subprocess-cells", action="store_true",
                    help="run each cell in a fresh subprocess (isolates "
                         "XLA state; resumable)")
    args = ap.parse_args()

    from ..configs import ASSIGNED, SHAPES, get_config

    archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    if args.subprocess_cells or (len(archs) > 1 or len(shapes) > 1):
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        for a in archs:
            for s in shapes:
                codec = args.codec or get_config(a).codec
                if already_done(args.out, (a, s, args.multi_pod, codec)):
                    print(f"cached: {a}/{s} multi_pod={args.multi_pod}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", args.out,
                       "--hnn-mode", args.hnn_mode]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                if args.codec:
                    cmd.extend(["--codec", args.codec])
                print(">>>", " ".join(cmd), flush=True)
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    append_result({"arch": a, "shape": s,
                                   "multi_pod": args.multi_pod,
                                   "codec": codec, "status": "error",
                                   "reason": f"exit {r.returncode}"},
                                  args.out)
        return

    arch, shape = archs[0], shapes[0]
    codec = args.codec or get_config(arch).codec
    try:
        rec = run_cell(arch, shape, args.multi_pod, codec, args.hnn_mode,
                       args.out)
    except Exception as e:
        traceback.print_exc()
        rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
               "codec": codec, "status": "error",
               "reason": f"{type(e).__name__}: {e}"[:500]}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        append_result(rec, args.out)
    print(json.dumps(rec, indent=1)[:2000])
    sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
