"""Analytic per-device cost model (FLOPs / HBM bytes / ICI wire bytes).

Why this exists: XLA's ``cost_analysis()`` counts a while-loop (scan)
body ONCE regardless of trip count (verified in EXPERIMENTS.md §Dry-run
notes), and our stacks scan over layer units — so the compiled numbers
are per-unit.  This module computes the exact structural totals from the
config, including:

  * our implementation's real attention cost (full S^2 chunked flash —
    the causal half is masked, not skipped: that waste shows up in the
    useful-FLOPs ratio on purpose),
  * remat policy (per-block checkpoint: backward recomputes the forward,
    including its boundary collectives and FSDP weight gathers),
  * codec-exact wire bytes (bf16 / int8 counts / packed uint4), with
    forward spike-coded and backward cotangents at bf16 (the paper
    sparsifies inference-direction traffic; coded-backward is a §Perf
    hillclimb lever).

Cross-check: parse_collectives() on the compiled HLO gives the per-unit
wire bytes; analytic per-unit values must match it (tested).
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeCell
from ..models.blocks_attn import attn_dims
from ..models.blocks_moe import moe_dims
from ..models.blocks_rnn import mlstm_dims, rwkv_dims
from ..models.blocks_ssm import ssm_dims
from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS

import math


@dataclasses.dataclass
class Cost:
    flops: float = 0.0          # per device
    hbm: float = 0.0            # per device bytes
    wire: float = 0.0           # per device ICI bytes

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.hbm + o.hbm,
                    self.wire + o.wire)

    def scaled(self, f=1.0, h=1.0, w=1.0):
        return Cost(self.flops * f, self.hbm * h, self.wire * w)


def wire_bytes_per_elem(codec: str) -> float:
    return {"none": 2.0, "int8": 1.0, "spike": 1.0, "spike_fused": 1.0,
            "spike_pack4": 0.5, "sparse_topk": 0.625}[codec]


def _boundary(B, S, D, tp, w):
    """One gather-in + one scatter-out of [B,S,D] over tp at w B/elem."""
    if tp == 1:
        return 0.0
    return 2 * (tp - 1) / tp * B * S * D * w


def block_cost(kind: str, cfg: ModelConfig, B: int, S: int, tp: int,
               dp: int, w: float) -> Cost:
    """Forward cost of one block on one device (gathered-seq domain)."""
    D = cfg.d_model
    c = Cost()
    act_b = 2.0  # bf16
    if kind in ("attn", "global", "local", "attn_moe"):
        d = attn_dims(cfg, tp)
        dh = d["dh"]
        hkv = d["Hkv"] if d["kv_rep"] else d["Hkv_loc"]
        c.flops += 2 * B * S * D * (d["Hq_loc"] + 2 * hkv) * dh  # qkv
        c.flops += 4 * B * S * S * d["Hq_loc"] * dh              # full-S^2
        c.flops += 2 * B * S * d["Hq_loc"] * dh * D              # out proj
        c.hbm += B * S * D * act_b * 6 + 2 * B * S * d["Hq_loc"] * dh * act_b
        c.wire += _boundary(B, S, D, tp, w)
        ffn = "moe" if kind == "attn_moe" else "mlp"
    elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
        d = ssm_dims(cfg, tp)
        Di, N, R = d["Di_loc"], d["N"], d["R"]
        c.flops += 2 * B * S * D * 2 * Di + 2 * B * S * Di * d["K"]
        c.flops += 2 * B * S * D * (2 * N + R) + 2 * B * S * R * Di
        c.flops += 14 * B * S * Di * N                      # scan + readout
        c.flops += 2 * B * S * Di * D
        c.hbm += B * S * (D * 2 + Di * 4) * act_b
        c.wire += _boundary(B, S, D, tp, w)
        ffn = {"mamba": None, "mamba_mlp": "mlp", "mamba_moe": "moe"}[kind]
    elif kind == "mlstm":
        d = mlstm_dims(cfg, tp)
        H, dh = d["H_loc"], d["dh"]
        c.flops += 2 * B * S * D * (4 * H * dh + 2 * H)
        c.flops += 6 * B * S * H * dh * dh
        c.flops += 2 * B * S * H * dh * D
        c.hbm += B * S * (D * 4 + H * dh * 4) * act_b
        c.wire += _boundary(B, S, D, tp, w)
        ffn = None
    elif kind == "slstm":
        d = mlstm_dims(cfg, tp)
        H, dh = d["H_loc"], d["dh"]
        c.flops += 2 * B * S * D * 4 * H * dh
        c.flops += 2 * B * S * H * dh * 4 * dh
        c.flops += 2 * B * S * H * dh * D
        c.hbm += B * S * D * 4 * act_b
        c.wire += _boundary(B, S, D, tp, w)
        ffn = None
    elif kind == "rwkv":
        d = rwkv_dims(cfg, tp)
        C_loc = d["C_loc"]
        F_loc = (cfg.ff_padded(tp) or 4 * D) // tp
        c.flops += 2 * B * S * D * 3 * C_loc + 12 * B * S * C_loc \
            + 2 * B * S * C_loc * D
        c.flops += 2 * B * S * (D * F_loc + F_loc * D + D * D)
        c.hbm += B * S * D * 8 * act_b
        c.wire += 2 * _boundary(B, S, D, tp, w)   # tm + cm boundaries
        ffn = None
    else:
        raise ValueError(kind)

    if kind in ("attn", "global", "local", "attn_moe", "mamba_mlp",
                "mamba_moe"):
        if ffn == "mlp":
            F_loc = cfg.ff_padded(tp) // tp
            c.flops += 6 * B * S * D * F_loc
            c.hbm += B * S * (2 * D + 3 * F_loc) * act_b
            c.wire += _boundary(B, S, D, tp, w)
        elif ffn == "moe":
            d = moe_dims(cfg, tp)
            T_loc = B * S // tp
            k = cfg.top_k
            C = max(1, math.ceil(T_loc * k / d["E"] * cfg.capacity_factor))
            c.flops += 2 * T_loc * D * d["E"]                 # router
            c.flops += 6 * d["E_loc"] * C * tp * D * d["Fe"]  # experts
            if d["Fs"]:
                c.flops += 6 * T_loc * D * d["Fs"]            # shared
            c.hbm += (d["E"] * C * D * 2 + T_loc * D * 2) * act_b
            # two all_to_alls of the [E, C, D] buffer
            c.wire += 2 * (tp - 1) / tp * d["E"] * C * D * w
    return c


def analytic_cost(cfg: ModelConfig, cell: ShapeCell, chips: int, tp: int,
                  mode: str, codec: str | None = None) -> Cost:
    """Total per-device cost for one step of ``mode``."""
    codec = codec or (cfg.codec if cfg.hnn_mode != "ann" else "none")
    w = wire_bytes_per_elem(codec)
    dp = chips // tp
    B_loc = max(1, cell.global_batch // dp)
    D, V = cfg.d_model, cfg.vocab_padded(tp)
    V_loc = V // tp
    p_total, _ = _param_count(cfg)
    p_dev_gathered = p_total * 2.0 / tp           # bf16, after dp-gather
    p_shard = p_total * 2.0 / (tp * dp)

    if mode in ("train", "prefill"):
        S = cell.seq_len if not cfg.is_encdec else cell.seq_len // 2
        fwd = Cost()
        for kind in cfg.pattern:
            fwd = fwd + block_cost(kind, cfg, B_loc, S, tp, dp, w)
        fwd = fwd.scaled(cfg.n_units, cfg.n_units, cfg.n_units)
        if cfg.is_encdec:
            enc = block_cost("attn", cfg, B_loc, S, tp, dp, w)
            cross = block_cost("attn", cfg, B_loc, S, tp, dp, w)
            fwd = fwd + enc.scaled(cfg.n_enc_layers, cfg.n_enc_layers,
                                   cfg.n_enc_layers) \
                + cross.scaled(cfg.n_units, cfg.n_units, cfg.n_units)
        # embedding scatter + head gather + head matmul
        head = Cost(2 * B_loc * S * D * V_loc,
                    B_loc * S * V_loc * 4 + V_loc * D * 2,
                    _boundary(B_loc, S, D, tp, w))
        # FSDP weight gathers (fwd) + weight/optimizer HBM traffic
        fsdp_w = (dp - 1) / dp * p_dev_gathered if dp > 1 else 0.0
        weights = Cost(0, p_dev_gathered, fsdp_w)

        if mode == "prefill":
            total = fwd + head + weights
            return total
        # train: fwd + remat-fwd + bwd(2x flops); collectives: coded fwd
        # runs twice (remat re-gathers), bwd transposes run at bf16
        bwd_wire_ratio = 2.0 / w                  # bf16 cotangents
        total = fwd.scaled(4.0, 3.0, 2.0 + bwd_wire_ratio) \
            + head.scaled(4.0, 3.0, 2.0 + bwd_wire_ratio) \
            + weights.scaled(1.0, 3.0, 3.0)       # fwd+remat gather+grad RS
        # optimizer state traffic: read p,m,v + write p,m,v (f32 moments)
        total.hbm += p_shard * (1 + 2 + 2) + p_shard * 2 * (2 + 2)
        return total

    # decode: one token; KV/state cache streamed once
    S = cell.seq_len
    cp = tp if cell.global_batch % dp == 0 else tp * dp
    B = B_loc if cell.global_batch % dp == 0 else cell.global_batch
    c = Cost()
    d = attn_dims(cfg, tp)
    for kind in cfg.pattern:
        if kind in ("attn", "global", "local", "attn_moe"):
            Ss = S // cp
            c.flops += 4 * B * d["Hq"] * d["dh"] * Ss      # cache attn
            c.flops += 2 * B * D * (d["Hq"] + 2 * d["Hkv"]) * d["dh"] / tp \
                + 2 * B * d["Hq_loc"] * d["dh"] * D
            c.hbm += B * Ss * d["Hkv"] * d["dh"] * 2 * 2   # k+v read
            c.wire += B * d["Hq"] * d["dh"] * 2 * 2        # q gather+psum
        elif kind.startswith("mamba"):
            sd = ssm_dims(cfg, tp)
            c.flops += 2 * B * D * 2 * sd["Di_loc"] \
                + 10 * B * sd["Di_loc"] * sd["N"] \
                + 2 * B * sd["Di_loc"] * D
            c.hbm += B * sd["Di_loc"] * sd["N"] * 4 * 2
            c.wire += B * D * 2 * 2
        elif kind in ("mlstm", "slstm"):
            md = mlstm_dims(cfg, tp)
            c.flops += 2 * B * D * 5 * md["H_loc"] * md["dh"] \
                + 6 * B * md["H_loc"] * md["dh"] ** 2
            c.hbm += B * md["H_loc"] * md["dh"] ** 2 * 4 * 2
            c.wire += B * D * 2 * 2
        elif kind == "rwkv":
            rd = rwkv_dims(cfg, tp)
            c.flops += 2 * B * D * 4 * rd["C_loc"] + 12 * B * rd["C_loc"]
            c.wire += 2 * B * D * 2 * 2
        if kind in ("attn_moe", "mamba_moe"):
            mdd = moe_dims(cfg, tp)
            C = max(1, math.ceil(B * cfg.top_k / mdd["E"] * 4.0))
            c.flops += 6 * mdd["E_loc"] * C * tp * D * mdd["Fe"]
            if mdd["Fs"]:
                c.flops += 6 * B * D * mdd["Fs"]
            c.wire += 2 * (tp - 1) / tp * mdd["E"] * C * D * w
        elif kind in ("attn", "global", "local", "mamba_mlp"):
            c.flops += 6 * B * D * cfg.ff_padded(tp) // tp
    c = c.scaled(cfg.n_units, cfg.n_units, cfg.n_units)
    # weights read once per token step (gathered per device)
    c.hbm += p_dev_gathered
    c.wire += (dp - 1) / dp * p_dev_gathered if dp > 1 else 0.0
    # head
    c.flops += 2 * B * D * V_loc
    c.hbm += V_loc * D * 2
    return c


def _param_count(cfg):
    from .roofline import count_params
    return count_params(cfg)


def terms(c: Cost):
    return {"compute_s": c.flops / PEAK_FLOPS, "memory_s": c.hbm / HBM_BW,
            "collective_s": c.wire / ICI_BW}
