"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single-pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips.  The "pod" axis folds
into the FSDP/data-parallel axes everywhere (DESIGN.md §4).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (smoke tests, examples)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(mesh) -> tuple[tuple[str, ...], str]:
    """(dp_axes, tp_axis) for a mesh built by this module."""
    names = mesh.axis_names
    assert names[-1] == "model", names
    return tuple(names[:-1]), "model"
