"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train_cli \
        --arch rwkv-paper --steps 300 --batch 8 --seq 128 \
        --mesh 1x1 --hnn-mode hnn --ckpt-dir /tmp/ckpt

Wires together: config -> mesh/plan -> sharded init -> AdamW train step
-> deterministic data pipeline -> fault-tolerant TrainLoop (checkpoint/
restart, straggler watch, NaN guard, preemption).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv-paper")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DPxTP, e.g. 2x4")
    ap.add_argument("--hnn-mode", default="hnn",
                    choices=["ann", "hnn", "snn"])
    ap.add_argument("--codec", default="spike_fused")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (smoke) config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lam", type=float, default=None,
                    help="sparsity penalty weight override")
    ap.add_argument("--target-rate", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=30)
    args = ap.parse_args(argv)

    from ..configs import get_config
    from ..configs.base import ShapeCell
    from ..configs.reduced import reduced as reduce_cfg
    from ..core.spike import SpikeConfig
    from ..data.pipeline import DataConfig, SyntheticLM
    from ..optim import adamw
    from ..runtime.ft import FTConfig, TrainLoop
    from . import specs as SP
    from . import train as TR
    from .mesh import make_mesh

    cfg = get_config(args.arch, hnn_mode=args.hnn_mode, codec=args.codec)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    cell = ShapeCell("cli", args.seq, args.batch, "train")
    plan = SP.make_plan(cfg, cell, mesh)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                total_steps=max(args.steps, 1))
    step, pspecs, ospecs, _ = TR.make_train_step(cfg, plan, mesh,
                                                 with_optimizer=True,
                                                 opt_cfg=opt_cfg)
    params = TR.init_sharded_params(cfg, plan, mesh,
                                    jax.random.PRNGKey(args.seed))
    opt = adamw.init_opt_state(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} mode={cfg.hnn_mode} codec={cfg.codec} "
          f"params={n_params/1e6:.2f}M mesh={mesh.shape}")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    hist = []

    def logged_step(p, o, batch):
        p, o, m = step(p, o, batch)
        hist.append(m)
        if len(hist) % args.log_every == 0:
            print(f"  step {len(hist):5d} loss={float(m['loss']):.4f} "
                  f"occ={float(m['occupancy']):.3f} "
                  f"pen={float(m['penalty']):.5f}")
        return p, o, m

    loop = TrainLoop(logged_step, data,
                     FTConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every))
    t0 = time.time()
    params, opt, metrics = loop.run(params, opt, args.steps,
                                    resume=not args.no_resume,
                                    mesh=mesh, pspecs=pspecs, ospecs=ospecs)
    dt = time.time() - t0
    out = {
        "arch": cfg.name, "mode": cfg.hnn_mode,
        "final_loss": metrics[-1]["loss"] if metrics else None,
        "final_occupancy": metrics[-1]["occupancy"] if metrics else None,
        "steps": len(metrics), "wall_s": round(dt, 1),
        "straggler_events": loop.straggler_events,
        "nan_skips": loop.nan_skips,
    }
    print("[train] done:", json.dumps(out))
    return out, metrics


if __name__ == "__main__":
    main()
