"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train_cli \
        --arch rwkv-paper --steps 300 --batch 8 --seq 128 \
        --mesh 1x1 --hnn-mode hnn --ckpt-dir /tmp/ckpt

Wires together: config -> mesh/plan -> sharded init -> AdamW train step
-> deterministic data pipeline -> fault-tolerant TrainLoop (checkpoint/
restart, straggler watch, NaN guard, preemption).

``--draft-heads K`` switches to the frozen-trunk draft-head mode
(``launch.train.make_draft_head_train_step``): K speculative draft
heads train against the next-k-token objective while the trunk stays
fixed, the optimizer covers only the heads, and checkpoints carry
trunk + heads as ONE params tree — exactly what the serving engine's
``drafter="heads"`` restores.  ``--init-from`` seeds the trunk from an
existing trunk-only checkpoint first (the usual flow: pretrain the
trunk, then bolt heads on).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv-paper")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DPxTP, e.g. 2x4")
    ap.add_argument("--hnn-mode", default="hnn",
                    choices=["ann", "hnn", "snn"])
    ap.add_argument("--codec", default="spike_fused")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (smoke) config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lam", type=float, default=None,
                    help="sparsity penalty weight override")
    ap.add_argument("--target-rate", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=30)
    ap.add_argument("--draft-heads", type=int, default=0,
                    help="train K frozen-trunk speculative draft heads "
                         "instead of the trunk (0: normal LM training)")
    ap.add_argument("--draft-hidden", type=int, default=0,
                    help="draft-head MLP hidden width (0: d_model // 2)")
    ap.add_argument("--init-from", default=None,
                    help="checkpoint dir to seed the TRUNK from before "
                         "heads-only training (trunk-only manifest)")
    args = ap.parse_args(argv)

    from ..configs import get_config
    from ..configs.base import ShapeCell
    from ..configs.reduced import reduced as reduce_cfg
    from ..core.spike import SpikeConfig
    from ..data.pipeline import DataConfig, SyntheticLM
    from ..optim import adamw
    from ..runtime.ft import FTConfig, TrainLoop
    from . import specs as SP
    from . import train as TR
    from .mesh import make_mesh

    cfg = get_config(args.arch, hnn_mode=args.hnn_mode, codec=args.codec)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    cell = ShapeCell("cli", args.seq, args.batch, "train")
    plan = SP.make_plan(cfg, cell, mesh)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                total_steps=max(args.steps, 1))
    params = TR.init_sharded_params(cfg, plan, mesh,
                                    jax.random.PRNGKey(args.seed))
    if args.draft_heads > 0:
        if args.init_from:
            from ..checkpoint.manager import CheckpointManager
            tspecs = TR.shard_params_specs(cfg, plan)[1]
            params, ck_step = CheckpointManager(args.init_from).restore(
                (params, adamw.init_opt_state(params)),
                mesh=mesh, specs=(tspecs, adamw.opt_state_specs(tspecs)))
            params = params[0]
            print(f"[train] trunk seeded from {args.init_from} "
                  f"step {ck_step}")
        step, pspecs, ospecs, _ = TR.make_draft_head_train_step(
            cfg, plan, mesh, args.draft_heads, args.draft_hidden,
            opt_cfg=opt_cfg)
        params["draft_heads"] = TR.init_draft_head_params(
            cfg, plan, mesh, jax.random.PRNGKey(args.seed + 1),
            args.draft_heads, args.draft_hidden)
        opt = adamw.init_opt_state(params["draft_heads"])
    else:
        step, pspecs, ospecs, _ = TR.make_train_step(cfg, plan, mesh,
                                                     with_optimizer=True,
                                                     opt_cfg=opt_cfg)
        opt = adamw.init_opt_state(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    mode = (f"draft_heads={args.draft_heads}" if args.draft_heads > 0
            else "lm")
    print(f"[train] {cfg.name} mode={cfg.hnn_mode} codec={cfg.codec} "
          f"params={n_params/1e6:.2f}M mesh={mesh.shape} train={mode}")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    hist = []

    def logged_step(p, o, batch):
        p, o, m = step(p, o, batch)
        hist.append(m)
        if len(hist) % args.log_every == 0:
            if "draft_acc" in m:
                print(f"  step {len(hist):5d} loss={float(m['loss']):.4f} "
                      f"draft_acc={float(m['draft_acc']):.3f}")
            else:
                print(f"  step {len(hist):5d} loss={float(m['loss']):.4f} "
                      f"occ={float(m['occupancy']):.3f} "
                      f"pen={float(m['penalty']):.5f}")
        return p, o, m

    loop = TrainLoop(logged_step, data,
                     FTConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every))
    t0 = time.time()
    params, opt, metrics = loop.run(params, opt, args.steps,
                                    resume=not args.no_resume,
                                    mesh=mesh, pspecs=pspecs, ospecs=ospecs)
    dt = time.time() - t0
    out = {
        "arch": cfg.name, "mode": cfg.hnn_mode,
        "final_loss": metrics[-1]["loss"] if metrics else None,
        "final_occupancy": (metrics[-1].get("occupancy")
                            if metrics else None),
        "steps": len(metrics), "wall_s": round(dt, 1),
        "straggler_events": loop.straggler_events,
        "nan_skips": loop.nan_skips,
    }
    if args.draft_heads > 0 and metrics:
        out["draft_acc"] = metrics[-1].get("draft_acc")
    print("[train] done:", json.dumps(out))
    return out, metrics


if __name__ == "__main__":
    main()
