"""Deterministic sharded token pipeline.

Production shape: each host reads only its shard of the stream, batches
are packed to fixed (B, S), and every batch is addressable by step index
(deterministic restart: resuming at step k reproduces batch k bit-exactly
without replaying the stream — the fault-tolerance contract).

Sources:
  * SyntheticLM     — seeded Markov-ish byte stream with learnable
                      structure (n-gram skeleton), used by examples/tests
                      (the container has no enwik8; §Accuracy uses this).
  * FileByteSource  — byte-level LM over a local file (enwik8-compatible
                      char-level setup from the paper, if a corpus is
                      mounted).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import queue
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1


class SyntheticLM:
    """Seeded synthetic byte LM with predictable n-gram structure.

    Tokens follow a sparse order-2 Markov chain derived from the seed, so
    a model can reach well-below-uniform perplexity quickly — giving the
    ANN/SNN/HNN accuracy comparison (paper Table 4) signal on CPU.
    """

    K = 8          # candidates per context
    NOISE = 0.05   # uniform-replacement rate

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab
        # order-1 chain: V contexts x K candidates, geometric weights —
        # dense enough that a small model sees every context often and
        # can reach the ~1.4-nat conditional entropy floor quickly
        self.table = rng.integers(0, V, size=(V, self.K)).astype(np.int32)
        w = 0.5 ** np.arange(self.K)
        self.probs = w / w.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        b_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            (cfg.seed, step, self.cfg.host_id, 0xBEEF))
        V = cfg.vocab
        toks = np.zeros((b_host, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, b_host)
        noise = rng.random((b_host, cfg.seq_len + 1)) < self.NOISE
        choice = rng.choice(self.K, size=(b_host, cfg.seq_len + 1),
                            p=self.probs)
        rand_tok = rng.integers(0, V, (b_host, cfg.seq_len + 1))
        for t in range(1, cfg.seq_len + 1):
            nxt = self.table[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class FileByteSource:
    """Byte-level LM batches from a file (enwik8-style char-level)."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self.data = np.fromfile(path, dtype=np.uint8)
        assert len(self.data) > cfg.seq_len + 1, path

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id))
        starts = rng.integers(0, len(self.data) - cfg.seq_len - 1, b_host)
        toks = np.stack([self.data[s:s + cfg.seq_len + 1] for s in starts])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch (overlap host data prep with device
    compute); preserves deterministic step indexing."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self.t.join(timeout=2)
