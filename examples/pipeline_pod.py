"""Pipeline parallelism across the pod axis with spike-coded stage sends.

DESIGN.md §4: the pod-boundary alternative to folding "pod" into FSDP is
pipeline stages — stage-boundary activations move by collective_permute,
and that ppermute carries the spike wire (the paper's die-to-die link,
literally: activations leaving one pod for the next).

This demo runs a 2-stage GPipe-style schedule over the reduced gemma2
stack on a ("pod"=2, "model"=1) mesh: stage 0 owns the first half of the
units + embedding, stage 1 the second half + head.  Each microbatch's
boundary activation crosses pods through ``coded_ppermute`` — compare
the wire bytes printed for codec none vs spike_pack4.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        python examples/pipeline_pod.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.reduced import reduced
from repro.core import boundary, spike
from repro.launch.mesh import make_mesh
from repro.launch.roofline import parse_collectives
from repro.models import model as M
from repro.models import params as PR
from repro.models.context import Context, codec_from_name


def build(codec_name):
    cfg = reduced(get_config("gemma2-2b"))
    mesh = make_mesh((2, 1), ("pod", "model"))
    codec = codec_from_name(codec_name, cfg.hnn_mode)
    ctx = Context(cfg=cfg, dp=("pod",), tp="model", dp_size=1, tp_size=1,
                  codec=codec, mode="train", collect_stats=False)

    defs = M.model_defs(cfg, 1)
    # both stages hold the full (tiny) params; each runs only its half
    params = PR.init_params(defs, jax.random.PRNGKey(0), cfg.dtype)
    n_units = cfg.n_units
    half = n_units // 2

    def stage_fn(params, tokens):
        """Per-pod stage: stage 0 embeds+runs units[:half], sends the
        boundary activation through the spike-coded ppermute; stage 1
        receives, runs units[half:], returns logits-mean as a probe."""
        pod = lax.axis_index("pod")
        aux = {"positions": jnp.broadcast_to(
            jnp.arange(tokens.shape[1])[None], tokens.shape)}

        x0 = M.embed_tokens(params, tokens, ctx)
        units = params["units"]
        take = lambda tree, lo, hi: jax.tree.map(lambda a: a[lo:hi], tree)

        def run_units(x, unit_tree):
            def body(c, u):
                x, = c
                x, _, _, _ = M._unit_fwd(u, None, x, ctx, aux)
                return (x,), None
            (x,), _ = lax.scan(body, (x,), unit_tree)
            return x

        x_a = run_units(x0, take(units, 0, half))
        # ---- pod boundary: stage 0 -> stage 1 (the paper's wire) ----
        sp = params["sp_head"]
        x_b_in = boundary.coded_ppermute(x_a, sp, ctx.codec, "pod",
                                         [(0, 1), (1, 0)])
        x_in = jnp.where(pod == 1, x_b_in, x_a)
        x_out = run_units(x_in, take(units, half, n_units))
        loss, _ = M.lm_loss_chunked(params, x_out,
                                    jnp.roll(tokens, -1, 1), ctx)
        return loss[None]   # rank-1 so out_specs can shard over "pod"

    fn = jax.shard_map(stage_fn, mesh=mesh,
                       in_specs=(P(), P()), out_specs=P("pod"),
                       check_vma=False)
    return jax.jit(fn), params, cfg


def main():
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 256,
                             jnp.int32)
    for codec in ("none", "spike_pack4"):
        fn, params, cfg = build(codec)
        lowered = fn.lower(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params),
            jax.ShapeDtypeStruct(tok.shape, tok.dtype))
        stats = parse_collectives(lowered.compile().as_text())
        loss = fn(params, tok)
        cp = stats.by_kind.get("collective-permute", 0.0)
        print(f"codec={codec:12s} stage-boundary ppermute bytes/step "
              f"{cp/1e3:8.1f} KB   per-pod loss probe "
              f"{np.array(loss).round(3)}")


if __name__ == "__main__":
    main()
