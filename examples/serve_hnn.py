"""Batched serving with spike-coded boundaries: prefill + decode loop.

    PYTHONPATH=src python examples/serve_hnn.py --arch qwen1.5-0.5b \
        --mesh 1x2 --batch 4 --prompt-len 64 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.configs.reduced import reduced
from repro.launch import serve as SV
from repro.launch import specs as SP
from repro.launch import train as TR
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mesh", default="1x2")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--hnn-mode", default="hnn")
    args = ap.parse_args()

    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    cfg = reduced(get_config(args.arch, hnn_mode=args.hnn_mode))
    S = args.prompt_len + args.gen
    cell = ShapeCell("serve", S, args.batch, "decode")
    plan = SP.make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    pre, *_ = SV.make_prefill_step(cfg, plan, mesh)
    dec, _, _ = SV.make_decode_step(cfg, plan, mesh)

    # pad prompts into the full-length cache (positions beyond prompt are
    # masked by pos during decode)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, S), 0, cfg.vocab, jnp.int32)
    t0 = time.time()
    logits, cache = pre(params, {"tokens": prompts, "labels": prompts})
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(nxt)
    t_pre = time.time() - t0

    out_tokens = [np.array(nxt)]
    t0 = time.time()
    for t in range(args.gen - 1):
        logits, cache = dec(params, cache, nxt,
                            jnp.asarray(args.prompt_len + t, jnp.int32))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.array(nxt))
    jax.block_until_ready(nxt)
    t_dec = time.time() - t0
    toks = args.batch * (args.gen - 1)
    print(f"{cfg.name} ({cfg.hnn_mode}): prefill {args.prompt_len} toks in "
          f"{t_pre*1e3:.0f}ms; decode {toks} toks in {t_dec*1e3:.0f}ms "
          f"({toks/max(t_dec,1e-9):.1f} tok/s on CPU)")
    print("sample:", np.stack(out_tokens, 1)[0][:16])


if __name__ == "__main__":
    main()
