"""Batched serving on the continuous-batching engine (repro.serving).

Admits a stream of variable-length requests into a fixed slot pool,
decodes all slots in lockstep with per-slot positions/temperatures and
fused on-device sampling, and keeps the spike wire on every decode-path
boundary collective.

    PYTHONPATH=src python examples/serve_hnn.py --arch qwen1.5-0.5b \
        --mesh 1x2 --slots 4 --requests 8 --prompt-len 16 --gen 16

Speculative decoding
--------------------
``--spec-k K`` turns on self-drafting speculative decoding: a
deterministic prompt-lookup (n-gram) drafter proposes K tokens per slot
from the slot's own committed history, and ONE batched verify step
scores all K+1 positions at once — the same coded collectives as a
decode step, carrying (K+1)x the D-space traffic, which is precisely
the boundary load the spike/int8 wire makes affordable.  The scheduler
keeps the longest draft prefix that matches the verify output plus the
model's correction token and rolls back the rejected tail's cache
occupancy.  Under greedy sampling (--temperature 0) the emitted token
streams are bit-identical to ``--spec-k 0``; only the step count drops.
Recurrent-state families (ssm/rnn/hybrid) silently fall back to
``spec_k=0`` — their state cannot roll back a rejected draft.

    PYTHONPATH=src python examples/serve_hnn.py --arch qwen1.5-0.5b \
        --mesh 1x2 --slots 4 --spec-k 3 --repetitive

``--repetitive`` makes the prompts cyclic so the drafter has something
to find; the report then shows ``accepted len > 1`` and the verify-step
wire bytes per committed token next to the vanilla decode wire.

Async decode streams
--------------------
``--async-depth 1`` runs the engine as a dispatch/commit pipeline: the
host launches decode step t+1 (feeding step t's sampled tokens straight
from the device array, no host round-trip) before it syncs step t, so
scheduling, admission prefill, and page bookkeeping overlap the device
step.  Greedy token streams are bit-identical to ``--async-depth 0``;
see ``benchmarks/serve_bench.py`` for the measured per-step latency
histogram.

Pool pressure + graceful degradation
------------------------------------
Shrink ``--num-pages`` below the dense reservation and the pool — not
the slot count — becomes the binding limit.  When a mid-decode slot
cannot map its next page, the engine (by default) evicts + re-queues
the youngest slot of the starving group and restarts it on re-admit:
greedy streams stay bit-identical, only latency pays, and the report
prints the preemption count.  ``--no-preempt`` restores the raw typed
``PagePoolExhausted``.  For SLO percentiles under trace-driven load and
injected faults, see ``benchmarks/slo_bench.py``.

    PYTHONPATH=src python examples/serve_hnn.py --mesh 1x2 --slots 4 \
        --page-size 8 --num-pages 10
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.configs.reduced import reduced
from repro.launch import specs as SP
from repro.launch import train as TR
from repro.launch.mesh import make_mesh
from repro.serving import EngineConfig, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mesh", default="1x2")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache length (0: prompt-len + gen)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV pool page size (positions per page)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page pool size (0: dense-equivalent "
                         "default — shrink it to make slots share)")
    ap.add_argument("--hnn-mode", default="hnn")
    ap.add_argument("--codec", default=None,
                    help="override cfg codec (none|int8|spike_fused|...)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft tokens per verify step "
                         "(0: vanilla decode)")
    ap.add_argument("--async-depth", type=int, default=0,
                    help="decode steps the host dispatches ahead of the "
                         "oldest un-synced step (1 overlaps host "
                         "scheduling with the device step; greedy "
                         "streams are token-identical to 0)")
    ap.add_argument("--repetitive", action="store_true",
                    help="cyclic prompts (speculative decoding's best "
                         "case: the n-gram drafter matches)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable pool-pressure preemption: a starving "
                         "slot raises typed PagePoolExhausted instead "
                         "of evicting + re-queueing the youngest slot")
    args = ap.parse_args()

    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))
    cfg = reduced(get_config(args.arch, hnn_mode=args.hnn_mode))
    if args.codec:
        cfg = cfg.replace(codec=args.codec)
    max_seq = args.max_seq or args.prompt_len + args.gen
    ecfg = EngineConfig(num_slots=args.slots, max_seq=max_seq,
                        prefill_len=args.prompt_len,
                        page_size=args.page_size,
                        num_pages=args.num_pages,
                        top_k=args.top_k, top_p=args.top_p,
                        spec_k=args.spec_k,
                        async_depth=args.async_depth,
                        preempt=not args.no_preempt)

    cell = ShapeCell("serve_decode", ecfg.max_seq, ecfg.num_slots, "decode")
    plan = SP.make_plan(cfg, cell, mesh)
    params = TR.init_sharded_params(cfg, plan, mesh, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, mesh, params, ecfg)

    rng = np.random.RandomState(1)

    def make_prompt():
        if args.repetitive:
            period = max(args.prompt_len // 4, 1)
            cycle = list(rng.randint(0, cfg.vocab, period))
            return (cycle * args.prompt_len)[:args.prompt_len]
        return list(rng.randint(0, cfg.vocab, args.prompt_len))

    reqs = [Request(rid=i, prompt=make_prompt(),
                    max_new_tokens=args.gen,
                    temperature=args.temperature)
            for i in range(args.requests)]

    engine.warmup(reqs[0].prompt)

    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    toks = engine.tokens_generated
    stats, per_tok = engine.decode_wire_stats()
    ps = engine.pool_stats()
    peak_kb = ps["peak_pages_in_use"] * engine.cache.kv_page_bytes() / 1e3
    print(f"{cfg.name} ({cfg.hnn_mode}/{cfg.codec}) mesh={args.mesh} "
          f"slots={args.slots}: served {len(results)} requests, "
          f"{toks} tokens in {dt*1e3:.0f}ms "
          f"({toks/max(dt, 1e-9):.1f} tok/s on CPU)")
    print(f"decode steps={engine.decode_steps}  "
          f"async depth={engine.async_depth}  "
          f"wire {per_tok/1e3:.1f}KB/token "
          f"({dict(stats.counts)} collectives/step)")
    print(f"kv pool: peak {ps['peak_pages_in_use']}/{ps['num_pages']} "
          f"pages x {ps['page_size']} positions  "
          f"mapped {peak_kb:.1f}KB at peak vs "
          f"{ps['kv_bytes_dense']/1e3:.1f}KB dense per-slot reservation")
    if engine.preemptions:
        print(f"pool pressure: {engine.preemptions} preemption(s) — "
              "evicted + re-queued youngest slots; greedy outputs are "
              "unchanged, only latency paid")
    if engine.spec_k > 0:
        mal = engine.mean_accepted_len
        _, vper_tok = engine.verify_wire_stats(mal)
        print(f"speculative: k={engine.spec_k}  accepted len={mal:.2f}  "
              f"verify wire {vper_tok/1e3:.1f}KB/committed-token")
    print("sample:", results[0][:16])


if __name__ == "__main__":
    main()
