"""Paper Table 4 analogue: ANN vs SNN vs HNN accuracy on a char-LM task.

Trains the paper's RWKV benchmark model (6L / 512d by default; --reduced
for CI speed) in all three modes on the deterministic synthetic byte LM
(no enwik8 in this container; same character-level setup) and reports
final loss / bits-per-char.  Expected ordering per the paper:
HNN ~= ANN (HNN may edge it out via the regularization effect), SNN worse.

    PYTHONPATH=src python examples/table4_accuracy.py --steps 200 --reduced
"""
import argparse
import json
import math

from repro.launch.train_cli import main as train_main


def run(mode, args):
    argv = ["--arch", "rwkv-paper", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--mesh", args.mesh, "--hnn-mode", mode,
            "--ckpt-dir", f"/tmp/t4_{mode}", "--no-resume",
            "--lr", "2e-3", "--log-every", "100"]
    if args.reduced:
        argv.append("--reduced")
    out, metrics = train_main(argv)
    tail = metrics[-10:]
    loss = sum(m["loss"] for m in tail) / len(tail)
    return {"mode": mode, "loss": loss, "bpc": loss / math.log(2),
            "occupancy": tail[-1]["occupancy"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    rows = [run(m, args) for m in ("ann", "snn", "hnn")]
    print("\n=== Table 4 analogue (char-LM, synthetic byte stream) ===")
    print(f"{'mode':6s} {'loss':>8s} {'bpc':>8s} {'occupancy':>10s}")
    for r in rows:
        print(f"{r['mode']:6s} {r['loss']:8.4f} {r['bpc']:8.4f} "
              f"{r['occupancy']:10.3f}")
    by = {r["mode"]: r for r in rows}
    print(json.dumps(rows))
    # paper ordering: SNN worst; HNN within noise of ANN
    assert by["snn"]["loss"] >= by["ann"]["loss"] - 0.02, "SNN beat ANN?"
    gap = by["hnn"]["loss"] - by["ann"]["loss"]
    print(f"\nHNN-ANN gap: {gap:+.4f} nats "
          f"({'HNN better' if gap < 0 else 'ANN better'}); "
          f"SNN-ANN gap: {by['snn']['loss'] - by['ann']['loss']:+.4f}")


if __name__ == "__main__":
    main()
