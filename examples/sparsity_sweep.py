"""Fig 7 analogue: sweep the sparsity regularizer and plot the tradeoff.

For each target rate, trains a small HNN and reports (loss, achieved
occupancy); the NoC simulator then converts occupancy to latency, giving
the paper's latency-vs-sparsity curve with the accuracy phase transition.

    PYTHONPATH=src python examples/sparsity_sweep.py --steps 120
"""
import argparse

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.configs.reduced import reduced
from repro.core.spike import SpikeConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import specs as SP
from repro.launch import train as TR
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.sim.noc import NocConfig, NocSim, PAPER_MODELS


def train_at(target_rate, lam, steps, seq=128, batch=8):
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("rwkv-paper"))
    cell = ShapeCell("sweep", seq, batch, "train")
    plan = SP.make_plan(cfg, cell, mesh)
    step, *_ = TR.make_train_step(cfg, plan, mesh, with_optimizer=True)
    # patch the codec's sparsity target via context: codec config lives in
    # the SpikeConfig; easiest is a config-level override
    import repro.launch.specs as SPM
    orig = SPM.codec_from_name

    def patched(name, mode):
        c = orig(name, mode)
        return dataclasses.replace(
            c, cfg=dataclasses.replace(c.cfg, target_rate=target_rate,
                                       lam=lam))
    SPM.codec_from_name = patched
    try:
        from repro.optim.adamw import AdamWConfig
        step, *_ = TR.make_train_step(
            cfg, plan, mesh, with_optimizer=True,
            opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=20,
                                total_steps=steps))
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        opt = adamw.init_opt_state(params)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                      global_batch=batch))
        m = {}
        for s in range(steps):
            params, opt, m = step(params, opt, data.batch(s))
        return float(m["loss"]), float(m["occupancy"])
    finally:
        SPM.codec_from_name = orig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    print(f"{'target':>7s} {'loss':>8s} {'occup.':>7s} "
          f"{'sim latency gain':>16s}")
    base = NocSim(NocConfig(mode="ann")).simulate(PAPER_MODELS["rwkv"]())
    for target in (0.5, 0.25, 0.10, 0.05, 0.02):
        loss, occ = train_at(target, lam=1.0, steps=args.steps)
        sim = NocSim(NocConfig(mode="hnn", spike_sparsity=1 - occ)) \
            .simulate(PAPER_MODELS["rwkv"]())
        print(f"{target:7.2f} {loss:8.4f} {occ:7.3f} "
              f"{base.latency_s / sim.latency_s:15.2f}x")


if __name__ == "__main__":
    main()
