"""Quickstart: build an HNN, run a train step and a decode step, and show
what the spike boundary puts on the wire.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCell, smoke_shape
from repro.configs.reduced import reduced
from repro.core import boundary, spike
from repro.launch import serve as SV
from repro.launch import specs as SP
from repro.launch import train as TR
from repro.launch.mesh import make_mesh


def main():
    # 1. the paper's core op: learnable spike encode -> int8 wire -> decode
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.5
    params = spike.init_spike_params(16)
    cfg_s = spike.SpikeConfig(T=15)
    counts = spike.encode(x, params, cfg_s)
    y = spike.decode(counts, params, cfg_s, jnp.float32)
    print("activation  :", np.array(x[0, :6]).round(3))
    print("spike counts:", np.array(counts[0, :6], np.int8))
    print("decoded     :", np.array(y[0, :6]).round(3))
    print(f"wire: {counts.size} int8 counts = "
          f"{counts.size} B vs {x.size * 2} B bf16 (2x; pack4 -> 4x)\n")

    # 2. an HNN model: train step + greedy decode on a tiny mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("gemma2-2b"))           # local/global + softcap
    cell = smoke_shape("train")
    plan = SP.make_plan(cfg, cell, mesh)
    step, *_ = TR.make_train_step(cfg, plan, mesh, with_optimizer=False)
    model_params = TR.init_sharded_params(cfg, plan, mesh,
                                          jax.random.PRNGKey(0))
    B, S = cell.global_batch, cell.seq_len
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab,
                             jnp.int32)
    loss, grads, m = step(model_params, {"tokens": tok,
                                         "labels": jnp.roll(tok, -1, 1)})
    print(f"gemma2 (reduced, HNN) train loss: {float(m['loss']):.3f}  "
          f"boundary occupancy: {float(m['occupancy']):.3f}")

    dcell = ShapeCell("d", S, B, "decode")
    dplan = SP.make_plan(cfg, dcell, mesh)
    pre, *_ = SV.make_prefill_step(cfg, dplan, mesh)
    dec, _, _ = SV.make_decode_step(cfg, dplan, mesh)
    logits, cache = pre(model_params, {"tokens": tok, "labels": tok})
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(4):
        logits, cache = dec(model_params, cache, nxt,
                            jnp.asarray(S - 1 + t, jnp.int32))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    print("greedy decode tokens:", np.array(nxt))


if __name__ == "__main__":
    main()
