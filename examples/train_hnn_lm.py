"""End-to-end driver: fault-tolerant HNN language-model training.

Default trains the paper's RWKV LM; pass --arch/--steps/--mesh to scale
(e.g. --arch qwen1.5-0.5b for a ~100M-class model on real hardware).

    PYTHONPATH=src python examples/train_hnn_lm.py --steps 300

Speculative draft heads (``--draft-heads K``) train K frozen-trunk
heads on the next-k-token objective and checkpoint them alongside the
trunk — the artifact the serving engine's ``drafter="heads"`` mode
restores:

    PYTHONPATH=src python examples/train_hnn_lm.py \
        --arch qwen1.5-0.5b --reduced --draft-heads 2 --steps 50 \
        --ckpt-dir /tmp/heads_ckpt
"""
import sys

from repro.launch.train_cli import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or
                                ["--arch", "rwkv-paper", "--steps", "300",
                                 "--batch", "8", "--seq", "128"])
    main()
