"""Roofline report: merge dry-run artifacts with the analytic cost model.

Produces the EXPERIMENTS.md §Roofline table: per (arch x shape), the
three terms (compute/memory/collective), the dominant bottleneck, the
MODEL_FLOPS/HLO ratio, and the HLO-parse cross-check.
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_config
from repro.launch import analytic as AN
from repro.launch import roofline as RL


def load(path="results/dryrun.jsonl"):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["multi_pod"],
                  r.get("codec"))] = r
    return recs


def row(arch, shape, rec, multi_pod=False, codec=None):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    chips = 512 if multi_pod else 256
    mode = cell.kind
    c = AN.analytic_cost(cfg, cell, chips, 16, mode, codec=codec)
    t = AN.terms(c)
    mf = RL.model_flops_per_chip(cfg, cell, chips, mode)
    dom = max(t, key=t.get)
    out = {
        "arch": arch, "shape": shape, "chips": chips,
        "compute_s": t["compute_s"], "memory_s": t["memory_s"],
        "collective_s": t["collective_s"],
        "bottleneck": dom.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / c.flops if c.flops else 0.0,
        "roofline_frac": t["compute_s"] / max(t.values()),
    }
    if rec and rec.get("status") == "ok":
        out["hlo_flops_per_unit"] = rec["roofline"]["flops"]
        out["hlo_wire_per_unit"] = rec["roofline"]["wire_bytes"]
        out["mem_temp_gb"] = rec["memory"]["temp_size_in_bytes"] / 1e9
        out["mem_args_gb"] = rec["memory"]["argument_size_in_bytes"] / 1e9
    return out


def table(multi_pod=False, emit=print):
    recs = load()
    emit(f"| arch | shape | compute s | memory s | collective s | "
         f"bottleneck | useful ratio | roofline frac |")
    emit("|---|---|---|---|---|---|---|---|")
    rows = []
    for arch in sorted({k[0] for k in recs} or
                       [a for a in __import__("repro.configs",
                                              fromlist=["ASSIGNED"]).ASSIGNED]):
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            key = [k for k in recs if k[0] == arch and k[1] == shape
                   and k[2] == multi_pod]
            rec = recs[key[0]] if key else None
            if rec and rec["status"] == "skipped":
                emit(f"| {arch} | {shape} | — | — | — | skipped "
                     f"(sub-quadratic gate) | — | — |")
                continue
            r = row(arch, shape, rec, multi_pod)
            rows.append(r)
            emit(f"| {arch} | {shape} | {r['compute_s']:.2e} | "
                 f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                 f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_frac']:.2f} |")
    return rows


def worst_cells(rows, n=3):
    by_frac = sorted(rows, key=lambda r: r["roofline_frac"])
    by_coll = sorted(rows, key=lambda r: -(r["collective_s"] /
                                           max(r["compute_s"], 1e-12)))
    return by_frac[:n], by_coll[:n]


if __name__ == "__main__":
    rows = table()
    wf, wc = worst_cells(rows)
    print("\nworst roofline fraction:",
          [(r["arch"], r["shape"], round(r["roofline_frac"], 3))
           for r in wf])
    print("most collective-bound:",
          [(r["arch"], r["shape"]) for r in wc])
