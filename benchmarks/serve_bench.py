"""Serving throughput benchmark: tokens/s + wire bytes/token per codec.

Runs the continuous-batching engine (>=4 slots) on a reduced config on
CPU, one pass per boundary codec, and reports

    serve/<codec>,us_per_token,tok/s=... wireKB/tok=...

in the ``name,us_per_call,derived`` CSV contract of benchmarks/run.py.
Wire bytes come from parsing the compiled batched decode step's
collectives (repro.launch.roofline), scaled across the mesh — the
headline serving-side artifact of the paper: the spike codec shrinks
the per-token die-to-die traffic while the scheduler keeps every slot
busy.  Alongside the wire numbers the report shows the KV page pool:
peak pages in use / pool size and the KV bytes actually mapped vs the
old dense per-slot reservation (``--num-pages`` sizes the pool; 0 =
dense-equivalent default).

With ``--spec-k K`` the engine runs self-drafting speculative decoding
and the report adds the verify-step wire bytes per committed token plus
the mean accepted draft length: the verify step multiplies the
decode-boundary traffic by K+1, which is exactly the term the coded
wire absorbs (vwireKB/tok already divides by the measured acceptance).

``--drafter`` picks who proposes those K tokens — ``ngram`` (host
prompt-lookup) or ``heads`` (learned draft heads living on device; the
verify step emits the next verify feed itself, so the dispatch chain
never joins the host) — or sweeps a comma list.  Before the first
``heads`` engine of each codec the bench trains the heads by
self-distillation: the trunk greedily rolls out the bench prompts, and
``--draft-train-steps`` heads-only steps fit those rollouts (the trunk
is random-init here, so its own rollouts are the ONLY distribution the
heads can usefully learn).  A drafter sweep shares one trunk init per
codec, so both drafters must emit identical greedy tokens (asserted);
acceptance and tokens/s are then the drafters' only degrees of freedom.
``--lowmatch`` draws every prompt without repeated tokens — the
prompt-lookup drafter's worst case and the learned heads' showcase.

With ``--async-depth 1`` the engine runs the dispatch/commit pipeline
(step t+1 launched before step t's tokens are synced).  The run is
driven step-by-step so every scheduler tick's host wall time is
measured individually, and the report appends a per-step latency
histogram — ``stepus p50/p95/p99`` — next to the mean: the overlap win
is a distribution shift the mean alone would hide, so it is measured,
not claimed.  Wire bytes per token are codec-determined and must not
move with the depth.

``--attn-kernel`` picks the paged decode/verify attention path —
``fused`` (default; the Pallas gather->flash->combine kernel over the
allocator's compacted per-shard page lists) or ``reference`` (dense
block-table gather + ``verify_attention_partial``) — or sweeps a comma
list of both.  A sweep shares one param init per codec, so the two
paths must emit identical greedy tokens (asserted) and the report
isolates the kernel's step-latency delta at identical wire bytes/token;
results are then keyed ``<codec>/<kernel>``.

With ``--disagg on`` (needs a dp>=2 mesh, e.g. ``--mesh 2x2``) the
engine splits prefill and decode across dp groups and every admission
migrates the finished prefill's paged KV to its decode group in one
coded ppermute (``--kv-wire`` picks the pow2-absmax int8 wire or fp).
``--disagg on,off`` sweeps both against one param init, asserts the
token streams are bit-identical (disaggregation is a placement change,
never a decode change), keys results ``<codec>/disagg-{on,off}``, and
reports migKB/req next to the EMIO cycles/token the migration traffic
adds to the step trace.

With ``--out BENCH_serve.json`` the same run also emits the structured
perf-trajectory artifact (schema ``bench_serve/v1``, see
``repro.serving.slo``): per-codec tokens/s, stepus/TTFT/TPOT
percentiles, wire KB/token and SLO attainment, recorded by an attached
``SLOMonitor``.  ``--trace-out steps.jsonl`` additionally exports the
per-step wire-bytes trace (one JSON line per scheduler tick) that
``repro.sim.noc.emio_cost_from_trace`` prices on the paper's EMIO model
— the serving-trace -> NoC co-simulation bridge.  With multiple codecs
the codec name is inserted before the trace file extension.

The step trace always carries the per-collective ``wire_streams``
breakdown (psum / head all-gather / partial combine / kv-migrate, from
``engine.wire_stream_profile()``'s HLO parse of the compiled steps).
``--cosim`` feeds it through the cycle-level NoC simulator
(``repro.sim.noc.NocSim.simulate_trace``): each result grows a
``cosim`` block — simulated joules/token, NoC cycles (and us) per
token, PE/MEM/Router/EMIO energy breakdown, per-stream wire KB — and
the run ends with a ranking of every codec/variant by simulated
joules per served token.  The cycle-level figure is asserted to bound
the closed-form eq (8) EMIO figure from above.

    PYTHONPATH=src python benchmarks/serve_bench.py [--mesh 1x2]
    PYTHONPATH=src python benchmarks/serve_bench.py --spec-k 3
    PYTHONPATH=src python benchmarks/serve_bench.py --async-depth 1
    PYTHONPATH=src python benchmarks/serve_bench.py --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time

CODECS = ("none", "int8", "spike_fused")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mesh", default="1x2")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--codecs", default=",".join(CODECS))
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV pool page size (positions per page)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page pool size (0: dense-equivalent "
                         "default, num_slots * pages_per_slot)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft tokens per verify step")
    ap.add_argument("--async-depth", type=int, default=0,
                    help="decode steps the host dispatches ahead of the "
                         "oldest un-synced step (0: synchronous loop)")
    ap.add_argument("--attn-kernel", default="fused",
                    help="paged decode/verify attention path: 'fused' "
                         "(Pallas kernel over compacted per-shard page "
                         "lists), 'reference' (dense gather), or a "
                         "comma list to sweep both — results are then "
                         "keyed <codec>/<kernel> so the fused-vs-"
                         "reference step-latency delta lands in one "
                         "BENCH_serve.json")
    ap.add_argument("--disagg", default="off",
                    help="disaggregated prefill/decode: 'on', 'off', or "
                         "a comma list to sweep both — results are then "
                         "keyed <codec>/disagg-{on,off}.  'on' needs a "
                         "dp>=2 mesh (e.g. --mesh 2x2): dp group 0 "
                         "prefills, the rest decode, and every admitted "
                         "request's KV migrates in one coded ppermute; "
                         "the report adds migKB/req and the sweep "
                         "asserts disagg token streams are identical to "
                         "colocated per codec")
    ap.add_argument("--kv-wire", default="coded",
                    help="KV migration wire format when --disagg is on: "
                         "'coded' (pow2-absmax int8, exact roundtrip) "
                         "or 'fp'")
    ap.add_argument("--repetitive", action="store_true",
                    help="cyclic prompts (the n-gram drafter's best case)")
    ap.add_argument("--lowmatch", action="store_true",
                    help="prompts without repeated tokens (the n-gram "
                         "drafter's worst case; the learned heads' "
                         "showcase)")
    ap.add_argument("--drafter", default="ngram",
                    help="speculative drafter: 'ngram' (host prompt-"
                         "lookup), 'heads' (device-side learned draft "
                         "heads; self-distilled here before serving), "
                         "or a comma list to sweep both — results are "
                         "then keyed <codec>/<drafter> and the sweep "
                         "asserts identical greedy streams")
    ap.add_argument("--draft-train-steps", type=int, default=200,
                    help="heads-only self-distillation steps per codec "
                         "when --drafter includes 'heads'")
    ap.add_argument("--out", default="",
                    help="write a bench_serve/v1 BENCH_serve.json here")
    ap.add_argument("--trace-out", default="",
                    help="write the per-step wire-bytes trace (JSONL) "
                         "for repro.sim.noc.emio_cost_from_trace")
    ap.add_argument("--cosim", action="store_true",
                    help="run the cycle-level NoC co-simulation over "
                         "each run's per-collective step trace: adds a "
                         "'cosim' block (simulated joules/token, NoC "
                         "cycles/us per token, PE/MEM/Router/EMIO "
                         "energy) to every result and ranks the "
                         "codecs/variants by simulated joules per "
                         "served token")
    args = ap.parse_args()

    dp, tp = (int(x) for x in args.mesh.split("x"))
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={dp * tp}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.serving import (EngineConfig, Request, ServingEngine,
                               SLOMonitor, make_bench_payload, write_bench)

    mesh = make_mesh((dp, tp), ("data", "model"))
    max_seq = args.prompt_len + args.gen
    rng = np.random.RandomState(0)
    if args.repetitive:
        period = max(args.prompt_len // 4, 1)
        prompts = [(list(rng.randint(0, 256, period))
                    * args.prompt_len)[:args.prompt_len]
                   for _ in range(args.requests)]
    elif args.lowmatch:
        # every prompt token distinct: prompt-lookup n-grams never match
        prompts = [list(rng.choice(256, min(args.prompt_len, 256),
                                   replace=False))
                   for _ in range(args.requests)]
    else:
        prompts = [list(rng.randint(0, 256, args.prompt_len))
                   for _ in range(args.requests)]

    baseline_tokens = None
    bench_results = {}
    codec_streams = {}
    codecs = args.codecs.split(",")
    kernels = args.attn_kernel.split(",")
    disagg_modes = args.disagg.split(",")
    for m in disagg_modes:
        if m not in ("on", "off"):
            raise SystemExit(f"--disagg must be on/off, got {m!r}")
    drafters = args.drafter.split(",")
    for m in drafters:
        if m not in ("ngram", "heads"):
            raise SystemExit(f"--drafter must be ngram/heads, got {m!r}")
    if "heads" in drafters and args.spec_k < 1:
        raise SystemExit("--drafter heads needs --spec-k >= 1")

    def distill_heads(cfg, params):
        """Train draft heads on the trunk's own greedy rollouts.

        The bench trunk is random-init, so the heads' training signal
        must come from the trunk itself (Medusa-style self-
        distillation): serve the bench prompts once without
        speculation, fit the heads on prompt+rollout for a few steps,
        and return trunk+heads as ONE tree.  The trunk flows through
        the heads-only step unchanged, so every engine in the sweep
        (ngram engines just ignore the heads subtree) shares bit-
        identical trunk weights.
        """
        from repro.optim import adamw
        eng = ServingEngine(cfg, mesh, params, EngineConfig(
            num_slots=args.slots, max_seq=max_seq,
            prefill_len=args.prompt_len, page_size=args.page_size,
            num_pages=args.num_pages))
        out = eng.run([Request(rid=i, prompt=p, max_new_tokens=args.gen)
                       for i, p in enumerate(prompts)])
        gl = min(len(out[i]) for i in range(len(prompts)))
        seqs = np.asarray([list(p) + list(out[i])[:gl]
                           for i, p in enumerate(prompts)], np.int32)
        S = ((seqs.shape[1] - 1) // tp) * tp
        B = max(dp, (len(prompts) // dp) * dp)
        seqs = np.resize(seqs, (B, seqs.shape[1]))
        batch = {"tokens": seqs[:, :S], "labels": seqs[:, 1:S + 1]}
        plan = SP.make_plan(cfg, ShapeCell("draft_distill", S, B,
                                           "train"), mesh)
        n = max(args.draft_train_steps, 1)
        step, _, _, _ = TR.make_draft_head_train_step(
            cfg, plan, mesh, args.spec_k,
            opt_cfg=adamw.AdamWConfig(lr=3e-2, warmup_steps=min(5, n),
                                      total_steps=n))
        params = dict(params)
        params["draft_heads"] = TR.init_draft_head_params(
            cfg, plan, mesh, jax.random.PRNGKey(1), args.spec_k)
        opt = adamw.init_opt_state(params["draft_heads"])
        m = {}
        for _ in range(args.draft_train_steps):
            params, opt, m = step(params, opt, batch)
        acc = float(m["draft_acc"]) if m else 0.0
        print(f"# distilled {args.spec_k} draft heads "
              f"({args.draft_train_steps} steps, "
              f"train draft_acc={acc:.3f})", file=sys.stderr)
        return params

    pairs = [(c, k, d, dr) for c in codecs for k in kernels
             for d in disagg_modes for dr in drafters]
    models = {}
    for codec, kernel, disagg, drafter in pairs:
        key = codec if len(kernels) == 1 else f"{codec}/{kernel}"
        if len(disagg_modes) > 1:
            key = f"{key}/disagg-{disagg}"
        if len(drafters) > 1:
            key = f"{key}/{drafter}"
        if codec not in models:
            hnn = "ann" if codec == "none" else "hnn"
            cfg = reduced(get_config(args.arch, hnn_mode=hnn)).replace(
                codec=codec)
            cell = ShapeCell("serve_decode", max_seq, args.slots, "decode")
            plan = SP.make_plan(cfg, cell, mesh)
            # one param init shared across the kernel/drafter sweep:
            # the attention paths and drafters must generate identical
            # tokens, so only step latency / acceptance may move
            params0 = TR.init_sharded_params(cfg, plan, mesh,
                                             jax.random.PRNGKey(0))
            if "heads" in drafters:
                params0 = distill_heads(cfg, params0)
            models[codec] = (cfg, params0)
        cfg, params = models[codec]
        ecfg = EngineConfig(num_slots=args.slots, max_seq=max_seq,
                            prefill_len=args.prompt_len,
                            page_size=args.page_size,
                            num_pages=args.num_pages,
                            spec_k=args.spec_k,
                            async_depth=args.async_depth,
                            attn_kernel=kernel,
                            disagg=(disagg == "on"),
                            kv_wire=args.kv_wire,
                            drafter=drafter)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=args.gen)
                for i, p in enumerate(prompts)]

        engine = ServingEngine(cfg, mesh, params, ecfg)
        engine.warmup(prompts[0])
        # per-collective per-step wire streams of every compiled step
        # kind (verify is profiled at accepted_len=1, so its stream sum
        # is the per-STEP bytes of one verify step)
        profile = engine.wire_stream_profile()
        per_tok = sum(profile["decode"].values()) / args.slots
        # attach AFTER warmup so the throwaway request's ticks never
        # contaminate the step trace or the SLO percentiles
        monitor = SLOMonitor(wire_streams_per_step=profile)
        engine.observers.append(monitor)

        # timestamp every scheduler tick so per-step host wall time is
        # measured individually: the async pipeline's win is a per-step
        # latency distribution shift, invisible to the mean
        ts = [time.perf_counter()]

        def tick(eng):
            ts.append(time.perf_counter())
            monitor.on_step(eng)

        results = engine.run(reqs, on_step=tick)
        dt = ts[-1] - ts[0]
        toks = engine.tokens_generated
        assert len(results) == args.requests
        # disagg is a placement change and the drafter is a proposal
        # change, never a decode change: greedy token streams must be
        # bit-identical across both sweeps
        ref_streams = codec_streams.setdefault((codec, kernel), results)
        assert results == ref_streams, (
            f"{key}: token streams diverge across the disagg/drafter "
            f"sweep")
        p50, p95, p99 = np.percentile(np.diff(np.asarray(ts)) * 1e6,
                                      [50, 95, 99])
        if baseline_tokens is None:
            baseline_tokens = toks
        assert toks == baseline_tokens, (
            f"{key} generated {toks} != {baseline_tokens} tokens; "
            "us_per_token not comparable across codecs/kernels")
        us_per_tok = dt / toks * 1e6
        ps = engine.pool_stats()
        extra = ""
        if engine.spec_k > 0:
            mal = engine.mean_accepted_len
            _, vper_tok = engine.verify_wire_stats(mal)
            extra = (f" drafter={drafter} spec_k={engine.spec_k} "
                     f"accepted={mal:.2f} "
                     f"vwireKB/tok={vper_tok/1e3:.2f} "
                     f"pipelined={engine.pipelined_dispatches}")
        if disagg == "on":
            mig_kb_req = (engine.migrated_wire_bytes / 1e3
                          / max(engine.migrations, 1))
            extra += (f" disagg={args.kv_wire} "
                      f"migKB/req={mig_kb_req:.1f}")
        peak_kb = ps["peak_pages_in_use"] * engine.cache.kv_page_bytes()
        print(f"serve/{key},{us_per_tok:.1f},"
              f"tok/s={toks/dt:.1f} wireKB/tok={per_tok/1e3:.2f} "
              f"steps={engine.decode_steps} slots={args.slots} "
              f"depth={args.async_depth} "
              f"stepus p50={p50:.0f} p95={p95:.0f} p99={p99:.0f} "
              f"pages={ps['peak_pages_in_use']}/{ps['num_pages']} "
              f"kvKBpeak={peak_kb/1e3:.1f} "
              f"kvKBdense={ps['kv_bytes_dense']/1e3:.1f}{extra}")
        rep = monitor.report()
        rep["wire_kb_per_tok"] = per_tok / 1e3
        # EMIO co-simulation headline off the same step trace (migration
        # bytes are folded into each tick's wire_bytes by the monitor)
        from repro.sim.noc import NocConfig, NocSim, emio_cost_from_trace
        trace_steps = monitor.step_trace()
        emio = emio_cost_from_trace(trace_steps)
        rep["emio_cycles_per_token"] = emio["emio_cycles_per_token"]
        if args.cosim:
            cosim = NocSim(NocConfig()).simulate_trace(
                trace_steps).to_dict()
            cosim["emio_closed_form_cycles_per_token"] = \
                emio["emio_cycles_per_token"]
            assert (cosim["noc_cycles_per_token"] + 1e-9
                    >= cosim["emio_closed_form_cycles_per_token"]), (
                f"{key}: cycle-level NoC simulation "
                f"({cosim['noc_cycles_per_token']:.1f} cyc/tok) below "
                f"the closed-form EMIO bound "
                f"({emio['emio_cycles_per_token']:.1f} cyc/tok)")
            rep["cosim"] = cosim
            print(f"# cosim {key}: "
                  f"J/tok={cosim['joules_per_token']:.3e} "
                  f"noc us/tok={cosim['noc_us_per_token']:.2f} "
                  f"cyc/tok={cosim['noc_cycles_per_token']:.0f} "
                  f"(closed-form "
                  f"{emio['emio_cycles_per_token']:.0f})",
                  file=sys.stderr)
        rep["mig_kb_per_req"] = (engine.migrated_wire_bytes / 1e3
                                 / max(engine.migrations, 1)
                                 if engine.migrations else 0.0)
        if engine.spec_k > 0:
            rep["drafter"] = drafter
            rep["pipelined_dispatches"] = engine.pipelined_dispatches
        bench_results[key] = rep
        if args.trace_out:
            path = args.trace_out
            if len(pairs) > 1:
                tag = key.replace("/", "-")
                stem, dot, ext = path.rpartition(".")
                path = f"{stem}.{tag}.{ext}" if dot else f"{path}.{tag}"
            monitor.write_trace(path)
            print(f"# step trace ({key}): {path}", file=sys.stderr)

    if args.cosim:
        ranked = sorted(bench_results.items(),
                        key=lambda kv: kv[1]["cosim"]["joules_per_token"])
        print("# cosim ranking (simulated joules per served token):",
              file=sys.stderr)
        for i, (k, r) in enumerate(ranked, 1):
            c = r["cosim"]
            print(f"#   {i}. {k}: {c['joules_per_token']:.3e} J/tok, "
                  f"{c['noc_us_per_token']:.2f} NoC-us/tok",
                  file=sys.stderr)

    if args.out:
        run_cfg = {
            "bench": "serve_bench", "arch": args.arch, "mesh": args.mesh,
            "slots": args.slots, "requests": args.requests,
            "prompt_len": args.prompt_len, "gen": args.gen,
            "page_size": args.page_size, "num_pages": args.num_pages,
            "spec_k": args.spec_k, "async_depth": args.async_depth,
            "attn_kernel": args.attn_kernel, "disagg": args.disagg,
            "kv_wire": args.kv_wire, "drafter": args.drafter,
            "lowmatch": args.lowmatch,
            "draft_train_steps": args.draft_train_steps,
            "cosim": args.cosim,
        }
        write_bench(args.out, make_bench_payload(run_cfg, bench_results))
        print(f"# BENCH_serve.json: {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
