"""Benchmark harness: one function per paper table/figure + system
microbenchmarks + roofline summary.

Prints ``name,us_per_call,derived`` CSV (harness contract).
"""
from __future__ import annotations

import sys
import time

ROWS = []


def emit(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def bench_kernels(emit):
    """Spike codec microbenchmarks (jnp closed-form path, CPU timings)."""
    import jax
    import jax.numpy as jnp
    from repro.core import spike

    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 1024))
    params = spike.init_spike_params(1024)
    cfg = spike.SpikeConfig(T=15)

    enc = jax.jit(lambda a: spike.encode(a, params, cfg).astype(jnp.int8))
    w = enc(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        w = enc(x).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    gbps = x.size * 4 / (us * 1e-6) / 1e9
    emit("kernel/spike_encode_4Mx", us, f"{gbps:.2f}GB/s")

    dec = jax.jit(lambda c: spike.decode(c.astype(jnp.float32), params,
                                         cfg, jnp.bfloat16))
    y = dec(w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        y = dec(w).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    emit("kernel/spike_decode_4Mx", us,
         f"{x.size * 1 / (us * 1e-6) / 1e9:.2f}GB/s")

    u8 = (w.astype(jnp.int32) + 7).astype(jnp.uint8) & 0xF
    pk = jax.jit(spike.pack4)
    p = pk(u8).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        p = pk(u8).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    emit("kernel/pack4_4Mx", us, f"2x_wire_reduction")


def bench_boundary_bytes(emit):
    """Wire-byte accounting per codec for a canonical boundary tensor."""
    from repro.launch.analytic import wire_bytes_per_elem
    B, S, D = 16, 4096, 8192
    base = B * S * D * 2
    for codec in ("none", "int8", "spike_fused", "spike_pack4",
                  "sparse_topk"):
        w = wire_bytes_per_elem(codec)
        emit(f"boundary/{codec}", 0.0,
             f"{base / (B * S * D * w):.2f}x_fewer_bytes")


def bench_roofline(emit):
    """§Roofline summary from the dry-run sweep (single-pod)."""
    from benchmarks.roofline_report import load, row
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    for (arch, shape, mp, codec), rec in sorted(recs.items()):
        if mp or rec.get("status") != "ok":
            continue
        t0 = time.perf_counter()
        r = row(arch, shape, rec, mp)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"roofline/{arch}/{shape}", us,
             f"bottleneck={r['bottleneck']};frac={r['roofline_frac']:.3f}")


def main() -> None:
    from benchmarks import paper_tables
    print("name,us_per_call,derived")
    for fn in paper_tables.ALL:
        fn(emit)
    bench_kernels(emit)
    bench_boundary_bytes(emit)
    bench_roofline(emit)
    print(f"# {len(ROWS)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()
