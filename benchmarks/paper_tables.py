"""Benchmarks reproducing the paper's tables/figures via the NoC sim.

One function per paper artifact:
  fig10_latency   — latency-per-inference speedup, 3 models x ANN/SNN/HNN
  fig11_sweeps    — speedup vs bit-width / NoC dims / grouping
  fig12_energy    — energy per inference + component breakdown
  fig13_energy_sweeps — energy efficiency vs the same sweeps
  fig7_sparsity   — latency improvement vs activation sparsity
"""
from __future__ import annotations

import time

from repro.sim.noc import NocConfig, NocSim, PAPER_MODELS

MODELS = ("rwkv", "msresnet18", "efficientnet-b4")


def _sim(model, mode, **kw):
    layers = PAPER_MODELS[model]()
    return NocSim(NocConfig(mode=mode, **kw)).simulate(layers)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def fig10_latency(emit):
    for m in MODELS:
        (reps, us) = _timed(lambda: {x: _sim(m, x) for x in
                                     ("ann", "snn", "hnn")})
        a, s, h = reps["ann"], reps["snn"], reps["hnn"]
        emit(f"fig10_latency/{m}/hnn_speedup", us,
             f"{a.latency_s / h.latency_s:.3f}x")
        emit(f"fig10_latency/{m}/snn_speedup", us,
             f"{a.latency_s / s.latency_s:.3f}x")
        emit(f"fig10_latency/{m}/latency_ms_hnn", us,
             f"{h.latency_s * 1e3:.4f}")


def fig11_sweeps(emit):
    for m in MODELS:
        for bits in (8, 16, 32):
            (r, us) = _timed(lambda: (_sim(m, "ann", bits=bits),
                                      _sim(m, "hnn", bits=bits)))
            emit(f"fig11_bits/{m}/b{bits}", us,
                 f"{r[0].latency_s / r[1].latency_s:.3f}x")
        for cpc in (8, 16, 64):
            (r, us) = _timed(lambda: (_sim(m, "ann", cores_per_chip=cpc),
                                      _sim(m, "hnn", cores_per_chip=cpc)))
            emit(f"fig11_noc/{m}/c{cpc}", us,
                 f"{r[0].latency_s / r[1].latency_s:.3f}x")
        for g in (64, 128, 256):
            (r, us) = _timed(lambda: (_sim(m, "ann", neurons_per_core=g),
                                      _sim(m, "hnn", neurons_per_core=g)))
            emit(f"fig11_group/{m}/g{g}", us,
                 f"{r[0].latency_s / r[1].latency_s:.3f}x")


def fig12_energy(emit):
    for m in MODELS:
        (reps, us) = _timed(lambda: {x: _sim(m, x) for x in
                                     ("ann", "snn", "hnn")})
        a, h = reps["ann"], reps["hnn"]
        emit(f"fig12_energy/{m}/hnn_gain", us,
             f"{a.total_energy / h.total_energy:.3f}x")
        bd = h.breakdown()
        tot = sum(bd.values()) or 1.0
        for k, v in bd.items():
            emit(f"fig12_energy/{m}/hnn_{k.lower()}_share", us,
                 f"{v / tot:.3f}")


def fig13_energy_sweeps(emit):
    for m in MODELS:
        for bits in (8, 16, 32):
            (r, us) = _timed(lambda: (_sim(m, "ann", bits=bits),
                                      _sim(m, "hnn", bits=bits)))
            emit(f"fig13_bits/{m}/b{bits}", us,
                 f"{r[0].total_energy / r[1].total_energy:.3f}x")
        for g in (64, 128, 256):
            (r, us) = _timed(lambda: (_sim(m, "ann", neurons_per_core=g),
                                      _sim(m, "hnn", neurons_per_core=g)))
            emit(f"fig13_group/{m}/g{g}", us,
                 f"{r[0].total_energy / r[1].total_energy:.3f}x")


def fig7_sparsity(emit):
    for m in MODELS:
        base = _sim(m, "ann")
        for sp in (0.80, 0.90, 0.95, 0.975):
            (h, us) = _timed(lambda: _sim(m, "hnn", spike_sparsity=sp))
            emit(f"fig7_sparsity/{m}/s{int(sp * 1000)}", us,
                 f"{base.latency_s / h.latency_s:.3f}x")


ALL = (fig10_latency, fig11_sweeps, fig12_energy, fig13_energy_sweeps,
       fig7_sparsity)
