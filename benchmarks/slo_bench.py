"""Trace-driven serving SLO benchmark: percentiles + attainment under
realistic (bursty, multi-tenant, long-tail) arrivals and injected
faults, emitted as the in-repo ``BENCH_serve.json`` perf trajectory.

Where serve_bench.py measures steady-state throughput on a fixed batch
of back-to-back requests, this driver replays a *seeded workload trace*
(``repro.serving.workload``): requests arrive over time, queue, collide
with pool pressure, and — with the fault knobs — get preempted,
suspended, or lose their replica mid-decode.  An ``SLOMonitor`` records
every lifecycle event and scheduler tick; the per-codec report carries
TTFT/TPOT/stepus p50/p95/p99, SLO attainment vs the targets, queue and
pool pressure peaks, and fault counters.  Greedy token streams stay
bit-identical across all injected faults (the engine restarts preempted
requests from scratch — tests/test_faults.py enforces it), so the SLO
numbers measure *latency* degradation, never correctness.

    PYTHONPATH=src python benchmarks/slo_bench.py --preset multitenant \\
        --horizon 4 --out BENCH_serve.json
    PYTHONPATH=src python benchmarks/slo_bench.py --p-preempt 0.05 \\
        --p-suspend 0.01 --preset bursty
    PYTHONPATH=src python benchmarks/slo_bench.py --smoke \\
        --out BENCH_serve.json          # the CI bench-smoke lane

``--trace-out`` exports the per-step wire-bytes trace (JSONL) that
``repro.sim.noc.emio_cost_from_trace`` prices on the paper's EMIO
die-to-die model, and the summary line prints that bridge's per-token
EMIO cycles/energy alongside the host-side numbers.

The step trace always carries the per-collective ``wire_streams``
breakdown (from ``engine.wire_stream_profile()``).  ``--cosim`` prices
it cycle-level through ``repro.sim.noc.NocSim.simulate_trace``: each
codec's result grows a ``cosim`` block (simulated joules/token, NoC
cycles/us per token, PE/MEM/Router/EMIO energy breakdown, per-stream
wire KB) and the run ends with a codec ranking by simulated joules per
served token — asserted to bound the closed-form eq (8) figure from
above.  The CI bench-smoke lane runs ``--smoke --cosim`` and gates on
the block's schema.
"""
from __future__ import annotations

import argparse
import os
import sys

CODECS = ("none", "spike_fused")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="engine prefill budget (trace prompts clamp)")
    ap.add_argument("--gen", type=int, default=16,
                    help="max generation length (trace draws clamp)")
    ap.add_argument("--codecs", default=",".join(CODECS))
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page pool (0: dense-equivalent; size it "
                         "BELOW the demand to exercise pool-pressure "
                         "preemption)")
    ap.add_argument("--spec-k", type=int, default=0)
    ap.add_argument("--async-depth", type=int, default=0)
    ap.add_argument("--drafter", default="ngram",
                    help="speculative drafter when --spec-k > 0: 'ngram' "
                         "or 'heads' (device-side draft heads; identity-"
                         "init here — this bench measures latency under "
                         "load/faults, acceptance lives in serve_bench)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode (needs dp>=2, "
                         "e.g. --mesh 2x2): migration bytes land in the "
                         "step trace and the EMIO pricing")
    ap.add_argument("--kv-wire", default="coded",
                    help="KV migration wire when --disagg: coded | fp")
    # -- workload ----------------------------------------------------------
    ap.add_argument("--preset", default="multitenant",
                    help="workload preset (steady/bursty/longtail/"
                         "multitenant)")
    ap.add_argument("--horizon", type=float, default=4.0,
                    help="trace horizon in trace-seconds")
    ap.add_argument("--load", type=float, default=8.0,
                    help="aggregate mean arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps-per-s", type=float, default=50.0,
                    help="logical replay clock: scheduler ticks per "
                         "trace-second")
    ap.add_argument("--wall", action="store_true",
                    help="replay on the host wall clock instead of the "
                         "deterministic logical clock")
    # -- faults ------------------------------------------------------------
    ap.add_argument("--p-preempt", type=float, default=0.0)
    ap.add_argument("--p-replica-loss", type=float, default=0.0)
    ap.add_argument("--p-suspend", type=float, default=0.0)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--max-faults", type=int, default=1 << 30)
    # -- SLO targets / outputs ---------------------------------------------
    ap.add_argument("--ttft-ms", type=float, default=500.0)
    ap.add_argument("--tpot-ms", type=float, default=100.0)
    ap.add_argument("--out", default="",
                    help="write a bench_serve/v1 BENCH_serve.json here")
    ap.add_argument("--trace-out", default="",
                    help="write the per-step wire-bytes trace (JSONL)")
    ap.add_argument("--cosim", action="store_true",
                    help="cycle-level NoC co-simulation over each "
                         "codec's per-collective step trace: adds a "
                         "'cosim' block (simulated joules/token, NoC "
                         "cycles/us per token, energy breakdown) per "
                         "codec and ranks codecs by simulated joules "
                         "per served token")
    ap.add_argument("--per-class", action="store_true",
                    help="print the per-tenant TTFT/TPOT split")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI trace: 2 slots, short horizon, one "
                         "fault of each kind, single-codec spike wire "
                         "on a 1x2 mesh (so boundary collectives — and "
                         "the --cosim figures — are non-vacuous)")
    args = ap.parse_args()

    if args.smoke:
        args.slots = 2
        args.prompt_len = 8
        args.gen = 8
        args.horizon = 1.0
        args.load = 10.0
        args.preset = "multitenant"
        args.codecs = "spike_fused"
        args.p_preempt = args.p_suspend = 0.08
        args.max_faults = 4
        if args.mesh == "1x1":
            # a 1x1 mesh compiles no collectives: every wire/cosim
            # figure would be a vacuous 0
            args.mesh = "1x2"

    dp, tp = (int(x) for x in args.mesh.split("x"))
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={dp * tp}")

    import jax

    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.configs.reduced import reduced
    from repro.launch import specs as SP, train as TR
    from repro.launch.mesh import make_mesh
    from repro.serving import (EngineConfig, FaultInjector, FaultPlan,
                               ServingEngine, SLOMonitor, SLOTargets,
                               make_bench_payload, preset_trace, replay,
                               write_bench)
    from repro.sim.noc import NocConfig, NocSim, emio_cost_from_trace

    mesh = make_mesh((dp, tp), ("data", "model"))
    max_seq = args.prompt_len + args.gen
    trace = preset_trace(args.preset, args.horizon, seed=args.seed,
                         prefill_len=args.prompt_len, max_gen=args.gen,
                         load=args.load)
    print(f"# trace: preset={args.preset} horizon={args.horizon}s "
          f"load={args.load}/s seed={args.seed} -> {len(trace)} requests",
          file=sys.stderr)
    targets = SLOTargets(ttft_ms=args.ttft_ms, tpot_ms=args.tpot_ms)
    plan_f = FaultPlan(seed=args.fault_seed, p_preempt=args.p_preempt,
                       p_replica_loss=args.p_replica_loss,
                       p_suspend=args.p_suspend,
                       max_faults=args.max_faults)

    bench_results = {}
    codecs = args.codecs.split(",")
    for codec in codecs:
        hnn = "ann" if codec == "none" else "hnn"
        cfg = reduced(get_config(args.arch, hnn_mode=hnn)).replace(
            codec=codec)
        ecfg = EngineConfig(num_slots=args.slots, max_seq=max_seq,
                            prefill_len=args.prompt_len,
                            page_size=args.page_size,
                            num_pages=args.num_pages,
                            spec_k=args.spec_k,
                            async_depth=args.async_depth,
                            disagg=args.disagg, kv_wire=args.kv_wire,
                            drafter=args.drafter)
        plan = SP.make_plan(cfg, ShapeCell("serve_decode", max_seq,
                                           args.slots, "decode"), mesh)
        params = TR.init_sharded_params(cfg, plan, mesh,
                                        jax.random.PRNGKey(0))
        if args.drafter == "heads" and args.spec_k > 0:
            params["draft_heads"] = TR.init_draft_head_params(
                cfg, plan, mesh, jax.random.PRNGKey(1), args.spec_k)
        engine = ServingEngine(cfg, mesh, params, ecfg)
        engine.warmup(trace.requests[0].req.prompt)

        # per-collective per-step wire streams of every compiled step
        # kind (verify profiled at accepted_len=1)
        profile = engine.wire_stream_profile()
        per_tok = sum(profile["decode"].values()) / args.slots
        monitor = SLOMonitor(targets=targets,
                             wire_streams_per_step=profile)
        injector = FaultInjector(plan_f)
        results = replay(engine, trace, observers=(monitor, injector),
                         steps_per_s=args.steps_per_s, wall=args.wall)
        assert len(results) == len(trace), (len(results), len(trace))

        rep = monitor.report()
        rep["wire_kb_per_tok"] = per_tok / 1e3
        bench_results[codec] = rep
        trace_steps = monitor.step_trace()
        emio = emio_cost_from_trace(trace_steps)
        if args.cosim:
            cosim = NocSim(NocConfig()).simulate_trace(
                trace_steps).to_dict()
            cosim["emio_closed_form_cycles_per_token"] = \
                emio["emio_cycles_per_token"]
            assert (cosim["noc_cycles_per_token"] + 1e-9
                    >= cosim["emio_closed_form_cycles_per_token"]), (
                f"{codec}: cycle-level NoC simulation below the "
                f"closed-form EMIO bound")
            rep["cosim"] = cosim
            print(f"# cosim {codec}: "
                  f"J/tok={cosim['joules_per_token']:.3e} "
                  f"noc us/tok={cosim['noc_us_per_token']:.2f} "
                  f"cyc/tok={cosim['noc_cycles_per_token']:.0f} "
                  f"(closed-form "
                  f"{emio['emio_cycles_per_token']:.0f})",
                  file=sys.stderr)
        slo = rep["slo"]
        print(f"slo/{codec},{rep['step_us']['p50']:.1f},"
              f"tok/s={rep['tokens_per_s']:.1f} "
              f"ttftms p50={rep['ttft_ms']['p50']:.1f} "
              f"p99={rep['ttft_ms']['p99']:.1f} "
              f"tpotms p50={rep['tpot_ms']['p50']:.1f} "
              f"p99={rep['tpot_ms']['p99']:.1f} "
              f"stepus p95={rep['step_us']['p95']:.0f} "
              f"attain={slo['attainment']:.2f} "
              f"wireKB/tok={per_tok/1e3:.2f} "
              f"preempt={rep['faults']['preemptions']} "
              f"suspend={rep['faults']['suspends']} "
              f"restarts={rep['requests']['restarts']} "
              f"emio cyc/tok={emio['emio_cycles_per_token']:.0f}"
              + (f" migKB/req={rep['migration']['kb_per_request']:.1f}"
                 if args.disagg else ""))
        if args.per_class:
            for cls, crep in monitor.per_class_report().items():
                print(f"#   {cls}: n={crep['finished']} "
                      f"ttftms p99={crep['ttft_ms']['p99']:.1f} "
                      f"tpotms p99={crep['tpot_ms']['p99']:.1f}",
                      file=sys.stderr)
        if args.trace_out:
            path = args.trace_out
            if len(codecs) > 1:
                stem, dot, ext = path.rpartition(".")
                path = f"{stem}.{codec}.{ext}" if dot else f"{path}.{codec}"
            monitor.write_trace(path)
            print(f"# step trace ({codec}): {path}", file=sys.stderr)

    if args.cosim:
        ranked = sorted(bench_results.items(),
                        key=lambda kv: kv[1]["cosim"]["joules_per_token"])
        print("# cosim ranking (simulated joules per served token):",
              file=sys.stderr)
        for i, (k, r) in enumerate(ranked, 1):
            c = r["cosim"]
            print(f"#   {i}. {k}: {c['joules_per_token']:.3e} J/tok, "
                  f"{c['noc_us_per_token']:.2f} NoC-us/tok",
                  file=sys.stderr)

    if args.out:
        run_cfg = {
            "bench": "slo_bench", "arch": args.arch, "mesh": args.mesh,
            "slots": args.slots, "prompt_len": args.prompt_len,
            "gen": args.gen, "page_size": args.page_size,
            "num_pages": args.num_pages, "spec_k": args.spec_k,
            "async_depth": args.async_depth, "drafter": args.drafter,
            "disagg": args.disagg, "kv_wire": args.kv_wire,
            "preset": args.preset,
            "horizon_s": args.horizon, "load": args.load,
            "seed": args.seed, "steps_per_s": args.steps_per_s,
            "requests": len(trace),
            "faults": {"seed": args.fault_seed,
                       "p_preempt": args.p_preempt,
                       "p_replica_loss": args.p_replica_loss,
                       "p_suspend": args.p_suspend,
                       "max_faults": args.max_faults},
            "slo_targets": {"ttft_ms": args.ttft_ms,
                            "tpot_ms": args.tpot_ms},
            "cosim": args.cosim,
        }
        write_bench(args.out, make_bench_payload(run_cfg, bench_results))
        print(f"# BENCH_serve.json: {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
